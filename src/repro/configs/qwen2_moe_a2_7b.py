"""qwen2-moe-a2.7b  [hf:Qwen/Qwen1.5-MoE-A2.7B]

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60 routed top-4
+ 4 shared experts (HF fuses the shared expert as one 5632-wide MLP; we model
it as 4 x 1408 experts, FLOP- and param-equivalent).
60 experts are padded to 64 on the 16-way `model` axis for expert parallelism.
"""
from repro.config import ModelConfig, register


@register("qwen2-moe-a2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=5632,            # shared-expert path width (4 x 1408)
        d_ff_expert=1408,
        vocab_size=151936,
        num_experts=60,
        num_shared_experts=4,
        top_k=4,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        param_sharding="fsdp",
    )
