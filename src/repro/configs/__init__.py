"""Assigned architecture configs (one module per arch, per the brief)."""
from repro.configs import (  # noqa: F401
    qwen2_moe_a2_7b,
    phi3_5_moe_42b_a6_6b,
    jamba_1_5_large_398b,
    internvl2_26b,
    qwen2_7b,
    qwen3_4b,
    llama3_8b,
    yi_9b,
    whisper_large_v3,
    mamba2_1_3b,
)

# Beyond-paper performance presets discovered in the EXPERIMENTS.md §Perf
# hillclimb.  Defaults stay paper-faithful-baseline; apply these via
#   get_config(arch, **PERF_PRESETS[arch])   or  dryrun --set k=v.
PERF_PRESETS = {
    "qwen2-moe-a2.7b": dict(moe_impl="ep", microbatch=16, remat=False),
    "phi3.5-moe-42b-a6.6b": dict(moe_impl="ep", microbatch=16),
    "jamba-1.5-large-398b": dict(moe_impl="ep", microbatch=16),
    # dense family: micro-batching brings train peak memory under the 16 GB
    # HBM budget at unchanged roofline terms (no-remat refuted on memory)
    "llama3-8b": dict(microbatch=8),
    "yi-9b": dict(microbatch=8),
    "qwen2-7b": dict(microbatch=8),
    "qwen3-4b": dict(microbatch=8),
}

ALL_ARCHS = (
    "qwen2-moe-a2.7b",
    "phi3.5-moe-42b-a6.6b",
    "jamba-1.5-large-398b",
    "internvl2-26b",
    "qwen2-7b",
    "qwen3-4b",
    "llama3-8b",
    "yi-9b",
    "whisper-large-v3",
    "mamba2-1.3b",
)
