"""jamba-1.5-large-398b  [arXiv:2403.19887]

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
Hybrid Mamba+attention with a 1:7 attn:mamba interleave (layer i is attention
iff i % 8 == 0 -> 9 attention layers / 63 mamba layers), MoE every 2nd layer.
Mamba d_state=128 assumed (brief gives none; mirrors the mamba2 entry).
"""
from repro.config import ModelConfig, register


@register("jamba-1.5-large-398b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=24576,
        d_ff_expert=24576,
        vocab_size=65536,
        num_experts=16,
        top_k=2,
        moe_every=2,
        attn_every=8,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=128,
        rope_theta=10_000.0,
        param_sharding="fsdp",
        opt_state_dtype="bfloat16",   # 398B: f32 m/v would not fit 16GB HBM at 256 chips
    )
