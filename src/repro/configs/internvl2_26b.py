"""internvl2-26b  [arXiv:2404.16821]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 — InternViT + InternLM2.
The InternViT-6B frontend is a STUB per the brief: input_specs() provides
precomputed patch embeddings (B, 1024, d_model) that are concatenated in front
of the token embeddings.  vocab padded 92553 -> 92672 (/16-divisible) for
vocab-parallel logits; padding rows are masked in the loss.
"""
from repro.config import ModelConfig, register


@register("internvl2-26b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92553,
        vision_patches=1024,
        frontend="vision",
        rope_theta=1_000_000.0,
        param_sharding="fsdp",
    )
