"""qwen2-7b  [arXiv:2407.10671]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — GQA, QKV bias.
28 heads are not divisible by the 16-way model axis; head-sharded attention
intermediates are padded 28->32 by GSPMD (~14% attention-FLOP padding).
"""
from repro.config import ModelConfig, register


@register("qwen2-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-7b",
        family="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        param_sharding="dp",
    )
