"""mamba2-1.3b  [arXiv:2405.21060]

48L d_model=2048 (attention-free) vocab=50280, ssm_state=128 — SSD
(state-space duality).  d_inner = 2*d_model = 4096, 64 heads x headdim 64,
causal depthwise conv k=4, chunked SSD scan (chunk=128).
vocab padded 50280 -> 50288 for vocab-parallel logits.
"""
from repro.config import ModelConfig, register


@register("mamba2-1.3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        tie_embeddings=True,
        param_sharding="dp",
    )
