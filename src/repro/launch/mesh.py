"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod = 16x16 = 256 chips (TPU v5e pod slice);
multi-pod = 2 pods x 256 = 512 chips with a leading "pod" axis that maps to
DCN-connected data parallelism.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} "
            "(dryrun.py sets XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    try:
        return jax.make_mesh(shape, axes, devices=devices[:n])
    except TypeError:  # older jax.make_mesh signature without devices kwarg
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    devices = jax.devices()
    dp = max(1, len(devices) // model_parallel)
    n = dp * model_parallel
    return Mesh(np.asarray(devices[:n]).reshape(dp, model_parallel),
                ("data", "model"))
