"""Step-function factories shared by dryrun / train / serve drivers, plus the
sharding trees for their inputs and outputs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.sharding import (ShardCtx, named_shardings, shard,
                                        use_shard_ctx, _axis_size)
from repro.models.model import Model
from repro.training.optimizer import AdamState, adamw_update, init_opt_state


def make_train_step(model: Model, tcfg: TrainConfig):
    cfg = model.cfg
    n_mb = max(tcfg.microbatch or cfg.microbatch, 1)

    def grad_fn(params, batch):
        return jax.value_and_grad(lambda p: model.train_loss(p, batch))(params)

    def train_step(params, opt_state: AdamState, batch):
        if n_mb > 1:
            # gradient accumulation: scan over microbatches, f32 accumulators
            def split(leaf):
                b = leaf.shape[0]
                return leaf.reshape(n_mb, b // n_mb, *leaf.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                acc_loss, acc_g = carry
                loss, g = grad_fn(params, mb)
                acc_g = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), acc_g, g)
                return (acc_loss + loss, acc_g), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss / n_mb
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
        else:
            loss, grads = grad_fn(params, batch)
        if tcfg.grad_compression == "int8":
            from repro.training.compression import compress_decompress
            grads = compress_decompress(grads)
        new_params, new_state, metrics = adamw_update(grads, opt_state, params, tcfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_serve_step(model: Model):
    """One decode step: greedy next-token + updated caches."""
    def serve_step(params, caches, token, pos):
        caches, logits = model.decode(params, caches, token, pos)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return caches, next_token
    return serve_step


# ----------------------------------------------------------------- shardings
def batch_shardings(ctx: ShardCtx, batch_spec: Dict[str, Any]):
    """Batch dim -> (pod,data); everything else replicated."""
    b = ctx.logical("batch")

    def one(path, leaf):
        spec = [b] + [None] * (leaf.ndim - 1)
        if leaf.shape[0] % _axis_size(ctx, b) != 0:
            spec[0] = None
        return NamedSharding(ctx.mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_spec)


def cache_shardings(ctx: ShardCtx, cache_spec: Any, seq_axes=None):
    """Decode caches: batch->(pod,data); attn KV seq dim -> model (+pod when
    batch can't use it, e.g. long_500k B=1); mamba heads/channels -> model."""
    b = ctx.logical("batch")
    m = ctx.logical("model")
    seq = seq_axes if seq_axes is not None else m

    def path_str(path):
        return "/".join(str(getattr(p, "key", p)) for p in path)

    def one(path, leaf):
        name = path_str(path).rsplit("/", 1)[-1]
        nd = leaf.ndim
        if name in ("k", "v"):          # (n?, B, S, K, hd)
            spec = [None] * (nd - 4) + [b, seq, None, None]
        elif name in ("xk", "xv"):      # (n?, B, F, K, hd) — cross KV, small
            spec = [None] * (nd - 4) + [b, None, None, None]
        elif name == "ssm":             # (n?, B, H, N, P)
            spec = [None] * (nd - 4) + [b, m, None, None]
        elif name.startswith("conv"):   # (n?, B, k-1, C)
            spec = [None] * (nd - 3) + [b, None, m]
        else:
            spec = [None] * nd
        # divisibility fallback
        fixed = []
        for dim, phys in enumerate(spec):
            if phys is not None and leaf.shape[dim] % _axis_size(ctx, phys) != 0:
                phys = None
            fixed.append(phys)
        return NamedSharding(ctx.mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(one, cache_spec)


def opt_state_shardings(ctx: ShardCtx, params_spec) -> Any:
    ps = named_shardings(ctx, params_spec)
    return AdamState(step=NamedSharding(ctx.mesh, P()), m=ps, v=ps)


def abstract_opt_state(params_spec, state_dtype: str) -> AdamState:
    dt = jnp.dtype(state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamState(step=jax.ShapeDtypeStruct((), jnp.int32),
                     m=jax.tree_util.tree_map(z, params_spec),
                     v=jax.tree_util.tree_map(z, params_spec))


def cell_functions(model: Model, shape: ShapeConfig, ctx: ShardCtx,
                   tcfg: Optional[TrainConfig] = None):
    """(jit-able fn, abstract args, in_shardings, out_shardings) for one cell."""
    cfg = model.cfg
    params_abs = model.init_abstract(max_seq=shape.seq_len + 8 if cfg.rope_theta <= 0 else 0)
    params_sh = named_shardings(ctx, params_abs)
    specs = model.input_specs(shape)

    if shape.kind == "train":
        tcfg = tcfg or TrainConfig()
        fn = make_train_step(model, tcfg)
        opt_abs = abstract_opt_state(params_abs, cfg.opt_state_dtype)
        opt_sh = opt_state_shardings(ctx, params_abs)
        b_sh = batch_shardings(ctx, specs["batch"])
        args = (params_abs, opt_abs, specs["batch"])
        in_sh = (params_sh, opt_sh, b_sh)
        out_sh = (params_sh, opt_sh, None)
        return fn, args, in_sh, out_sh

    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        b_sh = batch_shardings(ctx, specs["batch"])
        args = (params_abs, specs["batch"])
        return fn, args, (params_sh, b_sh), None

    # decode
    fn = make_serve_step(model)
    seq_axes = None
    if shape.global_batch == 1 and "pod" in ctx.mesh.axis_names:
        seq_axes = tuple(a for a in ("pod", "model") if a in ctx.mesh.axis_names)
    c_sh = cache_shardings(ctx, specs["caches"], seq_axes=seq_axes)
    t_sh = batch_shardings(ctx, {"t": specs["token"]})["t"]
    p_sh = NamedSharding(ctx.mesh, P())
    args = (params_abs, specs["caches"], specs["token"], specs["pos"])
    in_sh = (params_sh, c_sh, t_sh, p_sh)
    out_sh = (c_sh, t_sh)
    return fn, args, in_sh, out_sh
