"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --tiny \
      --steps 100 --ckpt /tmp/ckpt [--fail-at-step 40]

``--tiny`` swaps the full config for the reduced same-family config (CPU
runnable); the full configs are exercised via the dry-run.  ``--fail-at-step``
injects a failure to exercise the checkpoint/restart path end to end.
"""
from __future__ import annotations

import argparse

from repro.config import TrainConfig, get_config
from repro.data.pipeline import DataConfig
from repro.models.layers import padded_vocab
from repro.runtime.fault_tolerance import FailureInjector
from repro.testing import tiny_config
from repro.training.train_loop import run_training_with_restarts


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--full", dest="tiny", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8"])
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model: 512 x 8L)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = tiny_config(args.arch) if args.tiny else get_config(args.arch)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model, d_ff=4 * args.d_model)
    if args.layers:
        over.update(num_layers=args.layers)
    if over:
        cfg = cfg.replace(**over)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                       checkpoint_every=args.ckpt_every,
                       grad_compression=args.grad_compression)
    dcfg = DataConfig(vocab_size=min(cfg.vocab_size, 256),
                      seq_len=args.seq, global_batch=args.batch)
    injector = FailureInjector(args.fail_at_step)
    report = run_training_with_restarts(
        cfg, tcfg, dcfg, total_steps=args.steps,
        ckpt_dir=args.ckpt or "/tmp/repro_ckpt", injector=injector)
    print(f"[train] done: {report.steps_run} steps, restarts={report.restarts}, "
          f"first loss {report.losses[0]:.3f} -> last {report.losses[-1]:.3f}, "
          f"{report.wall_s:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
