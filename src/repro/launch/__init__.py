"""launch."""
