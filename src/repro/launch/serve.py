"""Serving driver: Hermes end to end on the real JAX engine.

  PYTHONPATH=src python -m repro.launch.serve --apps 12 --policy gittins

Builds the PDGraph knowledge base, spins up the tiny-model inference engine
with prefix/LoRA pools, converts each application's LLM units into real
engine requests (non-LLM units are host-side sleeps scaled down), and serves
them under the chosen policy with Hermes prewarming — the whole Fig. 4
architecture, with real tensors.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

import jax

from repro.apps.suite import SUITE, build_knowledge_base
from repro.apps.workload import make_workload
from repro.core.scheduler import HermesScheduler
from repro.models.model import build_model
from repro.serving.engine import InferenceEngine, Request
from repro.serving.lora import make_random_adapter
from repro.testing import tiny_config

# engine-scale token costs (tiny model on CPU)
T_IN = 2e-4
T_OUT = 2e-3
SCALE_TOKENS = 0.02          # scale app token counts down to engine scale


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=10)
    ap.add_argument("--policy", default="gittins")
    ap.add_argument("--window", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    kb = build_knowledge_base(n_trials=150, seed=3)
    insts = make_workload(args.apps, args.window, seed=args.seed,
                          t_in=T_IN, t_out=T_OUT)
    sched = HermesScheduler(kb, policy=args.policy, t_in=T_IN, t_out=T_OUT,
                            mc_walkers=128)

    cfg = tiny_config("llama3-8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prefixes: Dict[str, List[int]] = {}
    rngp = np.random.default_rng(7)
    for app in SUITE.values():
        for unit in app.units.values():
            if unit.backend.prefix:
                prefixes[unit.backend.prefix] = \
                    rngp.integers(1, cfg.vocab_size, size=24).tolist()
    eng = InferenceEngine(model, params, max_slots=4, max_seq=192,
                          prefix_prompts=prefixes)
    for app in SUITE.values():
        for unit in app.units.values():
            if unit.backend.lora and unit.backend.lora not in eng.lora.adapters:
                eng.lora.register(make_random_adapter(unit.backend.lora, params))

    t_start = time.monotonic()
    acts = {}
    rng = np.random.default_rng(args.seed)
    for inst in insts:
        sched.on_arrival(inst.app_id, inst.app_name, time.monotonic() - t_start)
        for unit, obs in inst.trajectory:
            node = kb[inst.app_name].units[unit]
            now = time.monotonic() - t_start
            sched.on_unit_start(inst.app_id, unit, now)
            # fire prewarm signals for downstream units
            for sig in sched.prewarm_signals(
                    inst.app_id, now,
                    lambda k: 0.05,
                    lambda k: (k.startswith("kv:") and k[3:] in eng.prefix.entries)
                    or (k.startswith("lora:") and eng.lora.is_warm(k[5:]))):
                key = sig.resource_key
                if key.startswith("kv:"):
                    eng.prewarm_prefix(key[3:])
                elif key.startswith("lora:"):
                    eng.prewarm_lora(key[5:])
            if node.backend.kind == "llm":
                n_out = max(2, int(obs["out"] * SCALE_TOKENS))
                ranks = sched.priorities(now)
                for j in range(int(obs["par"])):
                    eng.submit(Request(
                        req_id=f"{inst.app_id}.{unit}.{j}",
                        prompt=rng.integers(1, cfg.vocab_size, size=8).tolist(),
                        max_new_tokens=n_out, app_id=inst.app_id,
                        lora_id=node.backend.lora,
                        prefix_id=node.backend.prefix))
                eng.run(rank_fn=lambda r: ranks.get(r.app_id, 1e9))
                svc = obs["par"] * (obs["in"] * T_IN + obs["out"] * T_OUT)
            else:
                time.sleep(min(obs["dur"] * 0.002, 0.05))
                svc = obs["dur"]
            sched.on_progress(inst.app_id, svc)
        # final unit bookkeeping
        last_unit = inst.trajectory[-1][0]
        sched.on_unit_finish(inst.app_id, last_unit, inst.trajectory[-1][1],
                             time.monotonic() - t_start, None)
        acts[inst.app_id] = time.monotonic() - t_start - 0.0

    done = {r.req_id: r for r in eng.done}
    hits = sum(1 for r in eng.done if r.prefix_hit)
    total_p = sum(1 for r in eng.done if r.prefix_id)
    print(f"[serve] {len(insts)} apps, {len(done)} llm requests served")
    print(f"[serve] prefix hit ratio: {hits}/{total_p} "
          f"({hits/max(total_p,1):.0%}); lora merges: {eng.lora.merges}")
    print(f"[serve] mean ttft: "
          f"{1000*np.mean([r.ttft for r in eng.done if r.ttft]):.0f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
