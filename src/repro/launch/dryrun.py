import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
placeholder devices and extract the roofline terms from the compiled artifact.

  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single

Results are written incrementally to results/dryrun/<mesh>/<arch>__<shape>.json
(existing cells are skipped unless --force), so the full 2x40-cell sweep is
restartable — the fault-tolerance story applies to the tooling too.
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.config import (SHAPES, TPU_V5E, ModelConfig, applicable_shapes,
                          get_config, list_configs)
from repro.distributed.sharding import ShardCtx, use_shard_ctx
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_functions
from repro.models.model import build_model

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str):
    """Per-device wire bytes by collective type, parsed from partitioned HLO.

    all-reduce counts 2x operand (ring reduce+broadcast); all-gather counts its
    (post-gather) output; reduce-scatter / all-to-all / permute count operands.
    """
    per_op = {k: 0 for k in _COLLECTIVES}
    wire = 0
    count = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", rhs):
            continue  # counted at -start
        shapes = _SHAPE_RE.findall(rhs)
        if not shapes:
            continue
        paren = rhs.index("(")
        out_shapes = _SHAPE_RE.findall(rhs[:paren])
        in_shapes = _SHAPE_RE.findall(rhs[paren:])
        out_b = sum(_shape_bytes(d, s) for d, s in out_shapes)
        in_b = sum(_shape_bytes(d, s) for d, s in in_shapes) or out_b
        count += 1
        if op == "all-reduce":
            b = 2 * in_b
        elif op == "all-gather":
            b = out_b or in_b
        else:
            b = in_b
        per_op[op] += b
        wire += b
    per_op["total_wire_bytes"] = wire
    per_op["num_collectives"] = count
    return per_op


def tree_device_bytes(shardings, abstract) -> int:
    """Per-device resident bytes for a sharded abstract tree."""
    total = 0
    for sh, ab in zip(jax.tree_util.tree_leaves(shardings),
                      jax.tree_util.tree_leaves(abstract)):
        n = ab.dtype.itemsize
        for d in ab.shape:
            n *= d
        # shard count from the spec
        spec = getattr(sh, "spec", None)
        mesh = getattr(sh, "mesh", None)
        k = 1
        if spec is not None and mesh is not None:
            for entry in spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    k *= dict(mesh.shape)[a]
        total += n // k
    return total


def model_flops(cfg: ModelConfig, shape, n_devices: int) -> float:
    """6*N_active*tokens (train) / 2*N_active*tokens (fwd), per device."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        f = 2.0 * n_active * tokens
    else:
        f = 2.0 * n_active * shape.global_batch
    return f / n_devices


def _compile_cell(cfg: ModelConfig, shape, ctx, want_mem: bool):
    """Lower+compile one variant; return metrics from the compiled artifact."""
    model = build_model(cfg)
    t0 = time.time()
    with use_shard_ctx(ctx), ctx.mesh:
        fn, args, in_sh, out_sh = cell_functions(model, shape, ctx)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        out = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": collective_bytes(compiled.as_text()),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
        }
        if want_mem:
            try:
                mem = compiled.memory_analysis()
                out["memory_analysis"] = {
                    k: int(getattr(mem, k)) for k in
                    ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes")
                    if hasattr(mem, k)}
            except Exception:
                out["memory_analysis"] = {}
            out["params_bytes_per_dev"] = tree_device_bytes(in_sh[0], args[0])
    return out


def accounting_cfg(cfg: ModelConfig, k: int) -> ModelConfig:
    """Unrolled k-period variant with inner scans disabled, so cost_analysis
    and the HLO text count every op exactly once per layer."""
    from repro.models.transformer import layer_plan
    period = 1 if cfg.family == "encdec" else len(layer_plan(cfg))
    # microbatch=0: the accumulation scan is a while loop (counted once by
    # cost analysis); one full-batch step has the same per-step totals.
    over = dict(scan_layers=False, num_layers=k * period,
                attn_block_q=1 << 30, loss_chunk=1 << 30, microbatch=0)
    if cfg.family == "encdec":
        over["enc_layers"] = k
    return cfg.replace(**over)


def extrapolate(m1: dict, m2: dict, n: int) -> dict:
    """X_total = X(1 period) + (n-1) * (X(2 periods) - X(1 period))."""
    def ex(a, b):
        return max(0.0, a + (n - 1) * (b - a))
    coll = {k: ex(m1["coll"][k], m2["coll"][k]) for k in m1["coll"]}
    return {"flops": ex(m1["flops"], m2["flops"]),
            "bytes": ex(m1["bytes"], m2["bytes"]),
            "coll": coll}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             force: bool = False, overrides=None) -> dict:
    tag = "__".join(f"{k}-{v}" for k, v in sorted((overrides or {}).items()))
    fname = f"{arch}__{shape_name}" + (f"__{tag}" if tag else "") + ".json"
    out_path = out_dir / mesh_kind / fname
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    cfg = get_config(arch, **(overrides or {}))
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "overrides": overrides or {},
           "time": time.strftime("%Y-%m-%d %H:%M:%S")}
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        n_dev = mesh.devices.size
        ctx = ShardCtx(mesh, param_sharding=cfg.param_sharding)

        # 1) the real (scan-over-layers) program: proof of compile + memory
        main = _compile_cell(cfg, shape, ctx, want_mem=True)

        # 2) accounting variants: exact per-period costs, extrapolated
        from repro.models.transformer import n_periods as _np
        n = cfg.num_layers if cfg.family == "encdec" else _np(cfg)
        m1 = _compile_cell(accounting_cfg(cfg, 1), shape, ctx, want_mem=False)
        m2 = _compile_cell(accounting_cfg(cfg, 2), shape, ctx, want_mem=False)
        tot = extrapolate(m1, m2, n)

        hw = TPU_V5E
        mf = model_flops(cfg, shape, n_dev)
        compute_s = tot["flops"] / hw.peak_flops
        memory_s = tot["bytes"] / hw.hbm_bw
        coll_s = tot["coll"]["total_wire_bytes"] / hw.ici_bw
        dominant = max((("compute", compute_s), ("memory", memory_s),
                        ("collective", coll_s)), key=lambda kv: kv[1])[0]
        rec.update({
            "ok": True,
            "n_devices": int(n_dev),
            "lower_s": main["lower_s"], "compile_s": main["compile_s"],
            "hlo_flops_per_dev": tot["flops"],
            "hlo_bytes_per_dev": tot["bytes"],
            "collectives": tot["coll"],
            "scanned_program": {k: main[k] for k in ("flops", "bytes", "coll")},
            "memory_analysis": main.get("memory_analysis", {}),
            "params_bytes_per_dev": int(main.get("params_bytes_per_dev", 0)),
            "model_flops_per_dev": mf,
            "useful_flops_ratio": (mf / tot["flops"]) if tot["flops"] else None,
            "roofline": {
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dominant,
                "step_s_lower_bound": max(compute_s, memory_s, coll_s),
                "roofline_fraction": (compute_s / max(compute_s, memory_s, coll_s)
                                      if max(compute_s, memory_s, coll_s) else None),
            },
        })
    except Exception as e:  # record the failure; the sweep continues
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    out_path.write_text(json.dumps(rec, indent=2))
    status = "ok" if rec.get("ok") else "FAIL"
    dom = rec.get("roofline", {}).get("dominant", "-")
    print(f"[{status}] {mesh_kind:6s} {arch:24s} {shape_name:12s} "
          f"compile={rec.get('compile_s', 0):.0f}s dominant={dom}", flush=True)
    return rec


def cells_for(archs, shapes_filter=None, mesh_kinds=("single", "multi")):
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in applicable_shapes(cfg):
            if shapes_filter and shape_name not in shapes_filter:
                continue
            for mk in mesh_kinds:
                yield arch, shape_name, mk


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override k=v (e.g. moe_impl=ep)")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except Exception:
            pass
        overrides[k] = v

    archs = [args.arch] if args.arch else list(list_configs())
    shapes = [args.shape] if args.shape else None
    meshes = (args.mesh,) if args.mesh else ("single", "multi")
    out_dir = Path(args.out)

    n_fail = 0
    for arch, shape_name, mk in cells_for(archs, shapes, meshes):
        rec = run_cell(arch, shape_name, mk, out_dir, force=args.force,
                       overrides=overrides)
        n_fail += 0 if rec.get("ok") else 1
    print(f"done; failures={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
