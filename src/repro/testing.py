"""Tiny reduced configs (same family wiring, small dims) for CPU smoke tests."""
from __future__ import annotations

from repro.config import ModelConfig, get_config

# capacity_factor is generous so the sort/capacity MoE dispatch never drops
# tokens at tiny scale (drop-free => sort == dense oracle in tests)
_TINY_COMMON = dict(remat=False, scan_layers=True, moe_impl="sort",
                    capacity_factor=16.0)


def tiny_config(name: str, **extra) -> ModelConfig:
    """Reduced config of the same family as the full arch `name`."""
    cfg = get_config(name)
    over = dict(
        num_layers=max(2, len_plan(cfg)),
        d_model=64,
        d_ff=128,
        d_ff_expert=96 if cfg.d_ff_expert else 0,
        vocab_size=256,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.num_heads else 0,
        num_experts=4 if cfg.num_experts else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_frames=12 if cfg.enc_layers else 1500,
        vision_patches=8 if cfg.family == "vlm" else 1024,
        **_TINY_COMMON,
    )
    over.update(extra)
    return cfg.replace(**over)


def len_plan(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.attn_every * 2  # two periods
    return 2
