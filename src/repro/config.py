"""Configuration system for the Hermes-JAX framework.

Frozen dataclasses + a registry.  Every assigned architecture registers a
``ModelConfig`` in ``repro.configs``; shapes are ``ShapeConfig``s; hardware
constants live in ``HardwareConfig`` (TPU v5e by default).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0       # expert hidden size (0 -> d_ff)
    moe_every: int = 1         # MoE layer every n-th layer (others dense MLP)
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / jamba mamba layers) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    attn_every: int = 0        # hybrid: 1 attention layer per `attn_every` layers

    # --- flavor flags ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    act: str = "silu"          # silu (swiglu) | gelu (plain mlp, whisper)
    tie_embeddings: bool = False

    # --- frontend stubs / enc-dec ---
    frontend: str = "none"     # none | audio | vision
    enc_layers: int = 0        # whisper encoder depth
    enc_frames: int = 1500     # whisper stub frame count
    vision_patches: int = 1024 # internvl stub patch count

    # --- numerics / distribution ---
    dtype: str = "bfloat16"
    param_sharding: str = "fsdp"   # dp | zero1 | fsdp
    remat: bool = True
    remat_policy: str = "full"     # full | dots | offloadable
    microbatch: int = 0            # >1: grad-accumulation microbatches
    decode_f32_scores: bool = True # f32 accumulation in decode attention
    opt_state_dtype: str = "float32"
    moe_impl: str = "sort"     # sort (GSPMD) | ep (shard_map all_to_all) | dense (tiny/tests)
    attn_impl: str = "xla"     # xla | pallas (TPU only)
    scan_layers: bool = True
    attn_block_q: int = 256    # query-block size for the chunked XLA attention
    loss_chunk: int = 512      # seq-chunk size for vocab-sharded cross-entropy

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def has_decoder(self) -> bool:
        """Encoder-only archs have no decode step.  All ten assigned archs decode."""
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS) -------------------
    def param_counts(self) -> Dict[str, float]:
        """Return dict with total and active parameter counts (embedding incl.)."""
        D = self.d_model
        hd = self.resolved_head_dim()
        H, K = self.num_heads, self.num_kv_heads
        attn = D * (H * hd) + 2 * D * (K * hd) + (H * hd) * D
        if self.act == "silu":
            dense_mlp = 3 * D * self.d_ff
        else:
            dense_mlp = 2 * D * self.d_ff
        ffe = self.d_ff_expert or self.d_ff
        expert = 3 * D * ffe
        moe_mlp = self.num_experts * expert + self.num_shared_experts * expert + D * self.num_experts
        moe_active = (self.top_k + self.num_shared_experts) * expert + D * self.num_experts
        # mamba2 block params
        din, N, Hs = self.d_inner, self.ssm_state, self.ssm_heads
        mamba = D * (2 * din + 2 * N + Hs) + din * D + self.ssm_conv * (din + 2 * N) + 2 * Hs

        total = lay_active = 0.0
        for i in range(self.num_layers):
            if self.family in ("ssm",):
                total += mamba
                lay_active += mamba
                continue
            is_attn = True
            if self.family == "hybrid":
                is_attn = (self.attn_every > 0 and i % self.attn_every == 0)
            mixer = attn if is_attn else mamba
            if self.family in ("moe", "hybrid") and self.num_experts and ((i + 1) % self.moe_every == 0):
                total += mixer + moe_mlp
                lay_active += mixer + moe_active
            elif self.family in ("moe", "hybrid") and self.family == "moe" and self.num_experts:
                total += mixer + moe_mlp
                lay_active += mixer + moe_active
            else:
                total += mixer + dense_mlp
                lay_active += mixer + dense_mlp
        if self.family == "encdec":
            # encoder layers: self-attn + mlp;  decoder (num_layers) adds cross-attn
            total += self.enc_layers * (attn + dense_mlp)
            lay_active += self.enc_layers * (attn + dense_mlp)
            total += self.num_layers * attn  # cross attention
            lay_active += self.num_layers * attn
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return {"total": total + emb, "active": lay_active + emb}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class HardwareConfig:
    """TPU v5e roofline constants (per chip)."""
    name: str = "tpu_v5e"
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    hbm_bytes: float = 16e9          # capacity
    vmem_bytes: float = 128 * 2**20


TPU_V5E = HardwareConfig()


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    microbatch: int = 0              # 0 = no accumulation
    grad_compression: str = "none"   # none | int8
    checkpoint_every: int = 50
    label_smoothing: float = 0.0


# --------------------------------------------------------------------------
# registry
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str, **overrides) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]()
    return cfg.replace(**overrides) if overrides else cfg


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401
    return tuple(sorted(_REGISTRY))


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """Which of the four assigned shapes apply to this arch (brief rules)."""
    out = ["train_4k", "prefill_32k"]
    if cfg.has_decoder():
        out.append("decode_32k")
        if cfg.is_subquadratic():
            out.append("long_500k")
    return tuple(out)
