"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step) — no iterator state — so restart
from a checkpoint resumes on exactly the batch it would have seen (bit-exact
restart is tested), and each data-parallel rank can slice its shard of the
global batch independently (no central dispenser at 1000 nodes).

The stream is a mixture of structured patterns (ngram-ish markov chains) so a
~100M model has something learnable and the loss visibly decreases.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    markov_states: int = 64


def _markov_table(cfg: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed)
    k = cfg.markov_states
    table = rng.integers(0, cfg.vocab_size, size=(k, 8))
    return table


def batch_at(cfg: DataConfig, step: int, *, rank: int = 0,
             world: int = 1) -> Dict[str, np.ndarray]:
    """The (rank-th slice of the) global batch for `step`."""
    assert cfg.global_batch % world == 0
    per = cfg.global_batch // world
    rng = np.random.default_rng((cfg.seed, step, rank))
    table = _markov_table(cfg)
    k = table.shape[0]
    state = rng.integers(0, k, size=(per,))
    toks = np.empty((per, cfg.seq_len + 1), np.int32)
    for t in range(cfg.seq_len + 1):
        choice = rng.integers(0, table.shape[1], size=(per,))
        toks[:, t] = table[state, choice]
        state = (state * 31 + toks[:, t]) % k
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:].astype(np.int32),
        "loss_mask": np.ones((per, cfg.seq_len), np.float32),
    }


def data_iter(cfg: DataConfig, start_step: int = 0, *, rank: int = 0,
              world: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield batch_at(cfg, step, rank=rank, world=world)
        step += 1
