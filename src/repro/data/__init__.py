"""Data pipeline."""
