"""Reproduction of "Efficient Serving of LLM Applications with Probabilistic
Demand Modeling" (Hermes): PDGraph demand modeling, Gittins scheduling,
demand-aware prewarming, a cluster simulator, and JAX/Pallas model kernels."""

__version__ = "0.1.0"
