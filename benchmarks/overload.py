"""Overload-survival benchmark: goodput vs offered load under flash crowds.

Three serving arms run the SAME deterministic flash-crowd traces at a sweep
of offered-load points (spike multiplier x base load inside the spike
window):

* ``hermes_shed``  — hermes_ddl triage + SLO-class admission/shedding with
  per-tenant fairness + hysteresis degradation (the PR-7 overload stack);
* ``hermes_naive`` — hermes_ddl triage alone: hopeless work parks at the
  back of the queue but is never shed (pre-PR-7 behavior);
* ``edf``          — earliest-deadline-first baseline.

Per (load point, arm) the record carries ``goodput_per_s`` (SLO-attaining
completions per second of makespan — the metric shedding is graded on),
``goodput_service_s`` (useful service seconds delivered per second),
SLO-attainment overall and per class, and the shed/completion counts.
Everything is seeded and event-driven — goodput is bit-reproducible, so
the CI trend gate compares it exactly:

  python scripts/bench_trend.py BENCH_overload.json \
      --baseline benchmarks/baselines/BENCH_overload.smoke.json \
      --field goodput_per_s --direction max --min-ms 0

The sweep is followed by a **fault-injection canary**: the shedding arm
re-runs one overloaded point with a crash + staggered recovery plan in the
LLM pool, asserting the at-least-once contract — every non-shed
application completes, no unit is lost or double-counted, and each orphan
was re-queued exactly once.  A violation exits non-zero (the CI smoke leg
runs this benchmark, so the canary gates merges).

  PYTHONPATH=src python -m benchmarks.overload [--smoke]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

sys.path.insert(0, "src")  # repo-root invocation without an installed package

from benchmarks.common import kb  # noqa: E402
from repro.apps.suite import T_IN, T_OUT  # noqa: E402
from repro.apps.workload import make_flash_crowd_workload  # noqa: E402
from repro.core.admission import AdmissionConfig, DegradeConfig  # noqa: E402
from repro.runtime.fault_tolerance import FaultEvent  # noqa: E402
from repro.serving.backends import FaultConfig  # noqa: E402
from repro.serving.simulator import ClusterSim, SimConfig  # noqa: E402

JSON_PATH = "BENCH_overload.json"

# Load points are spike multipliers: offered load inside the spike window
# is mult x base_load, so 1.0 is the busy-but-stable operating point and
# everything past ~1.25/base_load is overloaded.  The sweep's overloaded
# points are where the shedding arm must dominate the naive arm (smoke is
# the same scenario, shorter trace + fewer points, feeding the CI gate).
FULL = dict(duration_s=240.0, base_load=0.8, spike_start=30.0,
            spike_dur=80.0, n_llm_slots=8, seed=6, kb_trials=120,
            mults=(1.0, 15.0, 20.0, 25.0))
SMOKE = dict(duration_s=240.0, base_load=0.8, spike_start=30.0,
             spike_dur=80.0, n_llm_slots=8, seed=6, kb_trials=120,
             mults=(1.0, 15.0, 20.0))

ARMS = ("hermes_shed", "hermes_naive", "edf")


def _trace(p, mult):
    return make_flash_crowd_workload(
        p["duration_s"], t_in=T_IN, t_out=T_OUT, base_load=p["base_load"],
        spike_mult=mult, spike_start=p["spike_start"],
        spike_dur=p["spike_dur"], n_service_slots=p["n_llm_slots"],
        with_deadlines=True, seed=p["seed"])


def _config(p, arm, faults=None):
    kw = dict(policy="hermes_ddl", seed=5, prewarm_mode="lru",
              n_llm_slots=p["n_llm_slots"], mc_walkers=64, faults=faults)
    if arm == "edf":
        kw["policy"] = "edf"
    elif arm == "hermes_shed":
        kw["admission"] = AdmissionConfig(pressure_watermark=1.0)
        kw["degrade"] = DegradeConfig(high_watermark=2.0, low_watermark=0.5,
                                      llm_speedup=2.0)
    return SimConfig(**kw)


def _row(name, mult, p, insts, res, wall):
    return {
        "name": name,
        "spike_mult": mult,
        "offered_load": mult * p["base_load"],
        "n_offered": len(insts),
        "completed": len(res.acts),
        "shed": len(res.shed),
        "makespan_s": res.makespan,
        "goodput_per_s": res.goodput(),
        "goodput_service_s": res.goodput_service_s(),
        "slo_attainment": res.slo_attainment(),
        "slo_attainment_standard": res.slo_attainment("standard"),
        "slo_attainment_best_effort": res.slo_attainment("best_effort"),
        "degraded_units": res.degrade_stats.get("degraded_units", 0.0),
        "wall_s": wall,
    }


def _fault_canary(p, knowledge):
    """One overloaded point with a crash mid-spike and a staggered
    recovery: the at-least-once contract must hold exactly."""
    mult = p["mults"][-1]
    insts = _trace(p, mult)
    faults = FaultConfig(
        events=(FaultEvent(t=p["spike_start"] + 20.0, kind="crash",
                           pool="llm", backend=1),
                FaultEvent(t=p["spike_start"] + 50.0, kind="recover",
                           pool="llm", backend=1)),
        n_backends=(("llm", 4),), heartbeat_timeout_s=1.0)
    sim = ClusterSim(knowledge, _config(p, "hermes_shed", faults=faults))
    res = sim.run(list(insts))
    by_id = {i.app_id: i for i in insts}
    offered = set(by_id)
    done, shed = set(res.acts), set(res.shed)
    problems = []
    if res.fault_stats.get("crashes", 0) < 1:
        problems.append("no crash was injected")
    if done | shed != offered or done & shed:
        problems.append("apps lost or double-terminal "
                        f"(done={len(done)} shed={len(shed)} "
                        f"offered={len(offered)})")
    if sorted(res.completion_order) != sorted(done) or \
            len(set(res.completion_order)) != len(res.completion_order):
        problems.append("completion order double-counts an app")
    short = [a for a in done
             if res.units_done[a] != len(by_id[a].trajectory)]
    if short:
        problems.append(f"{len(short)} apps completed with missing units")
    if res.fault_stats.get("requeued", 0) != \
            res.fault_stats.get("orphaned", 0):
        problems.append("orphan/requeue counts diverge")
    return {
        "spike_mult": mult,
        "crashes": res.fault_stats.get("crashes", 0.0),
        "orphaned": res.fault_stats.get("orphaned", 0.0),
        "requeued": res.fault_stats.get("requeued", 0.0),
        "lost_service_s": res.fault_stats.get("lost_service_s", 0.0),
        "completed": len(done),
        "shed": len(shed),
        "ok": not problems,
        "problems": problems,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short sweep for CI (same scenario, fewer points)")
    ap.add_argument("--out", default=JSON_PATH)
    args = ap.parse_args(argv)
    p = SMOKE if args.smoke else FULL

    knowledge = kb(p["kb_trials"])
    rows = []
    for mult in p["mults"]:
        insts = _trace(p, mult)
        for arm in ARMS:
            t0 = time.perf_counter()
            res = ClusterSim(knowledge, _config(p, arm)).run(list(insts))
            wall = time.perf_counter() - t0
            name = f"flash_x{mult:g}/{arm}"
            rows.append(_row(name, mult, p, insts, res, wall))
            r = rows[-1]
            print(f"{name:<28} offered={r['offered_load']:>4.1f} "
                  f"done={r['completed']:>3} shed={r['shed']:>3} "
                  f"goodput={r['goodput_per_s']:.4f}/s "
                  f"slo={r['slo_attainment']:.2f} ({wall:.1f}s wall)")

    # the PR's dominance contract, checked on every run: at every
    # overloaded point the shedding arm's goodput >= the naive arm's
    by_name = {r["name"]: r for r in rows}
    violations = []
    for mult in p["mults"]:
        if mult * p["base_load"] <= 1.0:
            continue
        g_shed = by_name[f"flash_x{mult:g}/hermes_shed"]["goodput_per_s"]
        g_naive = by_name[f"flash_x{mult:g}/hermes_naive"]["goodput_per_s"]
        if g_shed < g_naive:
            violations.append(f"x{mult:g}: shed {g_shed:.4f} < "
                              f"naive {g_naive:.4f}")

    canary = _fault_canary(p, knowledge)
    print(f"fault canary: crashes={canary['crashes']:g} "
          f"orphaned={canary['orphaned']:g} requeued={canary['requeued']:g} "
          f"ok={canary['ok']}")

    payload = {
        "benchmark": "overload",
        "smoke": args.smoke,
        "params": {k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in p.items()},
        "platform": f"{platform.system()}-{platform.machine()}",
        "python": platform.python_version(),
        "arms": list(ARMS),
        "rows": rows,
        "fault_canary": canary,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} rows)")

    if violations:
        print("overload: FAIL — shedding lost to naive at overloaded "
              "points:\n  " + "\n  ".join(violations))
        return 1
    if not canary["ok"]:
        print("overload: FAIL — fault canary violated the at-least-once "
              "contract:\n  " + "\n  ".join(canary["problems"]))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
