"""Fig. 11: deadline-satisfaction ratio (DSR) under deadline scaling
1.2x/1.5x/2x, Hermes-DDL vs EDF vs the non-deadline baselines."""
from __future__ import annotations

from benchmarks.common import Csv, run_policy, workload

POLICIES = {"vllm(fcfs_req)": "fcfs_req", "edf": "edf", "lstf(eq2)": "lstf",
            "hermes-ddl": "hermes_ddl"}


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    n, win = (300, 900.0) if paper_scale else (150, 450.0)
    if smoke:
        n, win = 24, 120.0
    insts = workload(n, win, seed=seed, deadlines=True)
    res = {}
    for name, pol in POLICIES.items():
        # Hermes-DDL is the full system (triage + prewarming); baselines are
        # the demand-agnostic systems, as in the paper's Fig. 11
        r = run_policy(insts, pol,
                       prewarm="hermes" if pol == "hermes_ddl" else "lru")
        res[name] = r
        csv.add(f"fig11/dsr/{name}", 0.0,
                f"all={r.dsr_ratio():.3f} tight={r.dsr_ratio('tight'):.3f} "
                f"modest={r.dsr_ratio('modest'):.3f} loose={r.dsr_ratio('loose'):.3f}")
    imp = res["hermes-ddl"].dsr_ratio() / max(res["edf"].dsr_ratio(), 1e-9) - 1
    csv.add("fig11/improvement_vs_edf", 0.0, f"+{100*imp:.0f}%")
    return res
