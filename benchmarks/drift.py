"""Drift-recovery benchmark: ACT recovery time after a mid-run demand shift.

At ``shift_at`` the workload's generating suite drifts (see
``repro.apps.workload.make_drifted_suite``): the LLM-heavy small
applications get ``demand_mult``x heavier per-unit demand plus new
self-repeat branch mass, while the arrival rate stays constant — so the
cluster runs contended on ground truth a stale model underestimates.
Three scheduler arms run the SAME deterministic trace:

* ``oracle``    — knowledge base profiled on the *drifted* suite (knows the
  post-shift truth from t=0; the recovery target);
* ``posterior`` — stale knowledge base + online conjugate posterior updates
  (``PosteriorConfig``): completions stream back as Dirichlet branch counts
  and Gamma demand scaling, so Gittins ranks re-learn the shift;
* ``frozen``    — the same stale knowledge base, never updated (pre-PR
  behavior).

Post-shift arrivals are bucketed into ``window_s`` arrival windows; each
arm's ``act_recovery_s`` is the first window start from which its windowed
mean ACT stays within ``(1 + tol)`` of the oracle arm's for every remaining
window (the post-shift horizon when it never settles).  The run FAILS
(exit 1) unless the posterior arm recovers strictly faster than the frozen
arm — the tentpole's dominance contract.  Everything is seeded and
event-driven, so ``act_recovery_s`` is bit-reproducible and the CI trend
gate compares it exactly:

  python scripts/bench_trend.py BENCH_drift.json \
      --baseline benchmarks/baselines/BENCH_drift.smoke.json \
      --field act_recovery_s --direction min --min-ms 0

  PYTHONPATH=src python -m benchmarks.drift [--smoke]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # repo-root invocation without an installed package

from repro.apps.suite import T_IN, T_OUT, build_knowledge_base  # noqa: E402
from repro.apps.workload import (TenantProfile,  # noqa: E402
                                 make_drift_workload, make_drifted_suite)
from repro.core.posterior import PosteriorConfig  # noqa: E402
from repro.serving.simulator import ClusterSim, SimConfig  # noqa: E402

JSON_PATH = "BENCH_drift.json"

# One tenant submitting the §5.1 mix minus the ten-minute-class apps (DM /
# MRS / LLMR would dominate every window's mean ACT and need hour-long
# traces to average out); the LLM-heavy drift subset is 43% of arrivals.
# rate_per_s keeps the llm slots contended-but-stable before the shift and
# pushed to the edge after it — the regime where a stale model's ordering
# mistakes cost ACT every window.
MIX = {"EV": 0.144, "FEV": 0.144, "CC": 0.144, "ALFWI": 0.144,
       "KBQAV": 0.144, "CG": 0.13, "PE": 0.13}
DRIFT_APPS = ("FEV", "ALFWI", "KBQAV")

FULL = dict(duration_s=600.0, shift_at=120.0, rate_per_s=0.3,
            demand_mult=3.0, p_repeat=0.35, n_llm_slots=8, window_s=60.0,
            tol=0.25, kb_trials=120, seed=11)
SMOKE = dict(duration_s=360.0, shift_at=60.0, rate_per_s=0.3,
             demand_mult=3.0, p_repeat=0.35, n_llm_slots=8, window_s=60.0,
             tol=0.25, kb_trials=120, seed=11)

ARMS = ("oracle", "posterior", "frozen")


def _trace(p):
    return make_drift_workload(
        p["duration_s"], t_in=T_IN, t_out=T_OUT, shift_at=p["shift_at"],
        rate_per_s=p["rate_per_s"], demand_mult=p["demand_mult"],
        p_repeat=p["p_repeat"], drift_apps=DRIFT_APPS,
        n_service_slots=p["n_llm_slots"],
        tenants=[TenantProfile(name="t0", app_mix=MIX)], seed=p["seed"])


def _config(p, arm):
    return SimConfig(
        policy="gittins", seed=5, prewarm_mode="lru",
        n_llm_slots=p["n_llm_slots"], mc_walkers=64,
        posterior=PosteriorConfig() if arm == "posterior" else None)


def _knowledge(p, arm):
    if arm == "oracle":
        drifted = make_drifted_suite(demand_mult=p["demand_mult"],
                                     p_repeat=p["p_repeat"],
                                     drift_apps=DRIFT_APPS)
        return build_knowledge_base(n_trials=p["kb_trials"], seed=3,
                                    apps=drifted)
    return build_knowledge_base(n_trials=p["kb_trials"], seed=3)


def _windowed_act(p, insts, res):
    """Mean ACT of post-shift arrivals, bucketed by arrival-time window
    (window starts are seconds after the shift)."""
    horizon = p["duration_s"] - p["shift_at"]
    n_win = int(np.ceil(horizon / p["window_s"]))
    starts = [i * p["window_s"] for i in range(n_win)]
    sums, counts = [0.0] * n_win, [0] * n_win
    for inst in insts:
        if not inst.app_id.startswith("drift") or inst.app_id not in res.acts:
            continue
        w = min(int((inst.arrival - p["shift_at"]) // p["window_s"]),
                n_win - 1)
        sums[w] += res.acts[inst.app_id]
        counts[w] += 1
    return starts, [s / c if c else float("nan")
                    for s, c in zip(sums, counts)]


def _recovery_s(p, starts, acts, oracle_acts):
    """First window start from which windowed ACT stays within
    (1 + tol) x oracle for every remaining window; the post-shift horizon
    when the arm never settles."""
    horizon = p["duration_s"] - p["shift_at"]
    ok = [not (a > (1.0 + p["tol"]) * o)  # NaN (empty window) passes
          for a, o in zip(acts, oracle_acts)]
    for i, t in enumerate(starts):
        if all(ok[i:]):
            return float(t)
    return float(horizon)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same scenario)")
    ap.add_argument("--out", default=JSON_PATH)
    args = ap.parse_args(argv)
    p = SMOKE if args.smoke else FULL

    insts = _trace(p)
    n_post = sum(1 for i in insts if i.app_id.startswith("drift"))
    print(f"drift trace: {len(insts)} apps ({n_post} post-shift), "
          f"shift at {p['shift_at']:g}s, x{p['demand_mult']:g} demand on "
          f"{'/'.join(DRIFT_APPS)}")

    rows, windowed = [], {}
    for arm in ARMS:
        t0 = time.perf_counter()
        res = ClusterSim(_knowledge(p, arm), _config(p, arm)).run(list(insts))
        wall = time.perf_counter() - t0
        starts, acts = _windowed_act(p, insts, res)
        windowed[arm] = (starts, acts)
        rows.append({
            "name": arm,
            "completed": len(res.acts),
            "mean_act_s": res.mean_act(),
            "post_shift_mean_act_s": float(np.nanmean(acts)),
            "window_starts_s": starts,
            "windowed_act_s": acts,
            "wall_s": wall,
        })
        print(f"{arm:<10} done={rows[-1]['completed']:>3} "
              f"post-shift ACT={rows[-1]['post_shift_mean_act_s']:.1f}s "
              f"windows=[" +
              " ".join(f"{a:.0f}" for a in acts) + f"] ({wall:.1f}s wall)")

    oracle_acts = windowed["oracle"][1]
    for row in rows:
        starts, acts = windowed[row["name"]]
        row["act_recovery_s"] = _recovery_s(p, starts, acts, oracle_acts)

    by_name = {r["name"]: r for r in rows}
    rec_post = by_name["posterior"]["act_recovery_s"]
    rec_frozen = by_name["frozen"]["act_recovery_s"]
    # None (JSON null) when the posterior arm never left the oracle's
    # tolerance band — the ratio is unbounded
    ratio = rec_frozen / rec_post if rec_post > 0 else None
    print(f"recovery: posterior={rec_post:g}s frozen={rec_frozen:g}s "
          f"(frozen/posterior = "
          f"{'inf' if ratio is None else f'{ratio:g}'}x)")

    payload = {
        "benchmark": "drift",
        "smoke": args.smoke,
        "params": dict(p, drift_apps=list(DRIFT_APPS)),
        "platform": f"{platform.system()}-{platform.machine()}",
        "python": platform.python_version(),
        "arms": list(ARMS),
        "recovery_ratio": ratio,
        "rows": rows,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} rows)")

    if rec_post >= rec_frozen:
        print(f"drift: FAIL — posterior arm did not recover faster than "
              f"frozen ({rec_post:g}s >= {rec_frozen:g}s)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
