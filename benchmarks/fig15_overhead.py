"""Fig. 15: scheduling-policy runtime — (a) Gittins cost vs queue size
(arrival rate), (b) vs bucket count; plus the end-to-end priorities() path."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv, kb, run_policy, workload
from repro.core.gittins import gittins_rank_hist, to_histogram


def _time_gittins(n_jobs: int, n_buckets: int, iters: int = 50) -> float:
    rng = np.random.default_rng(0)
    probs, edges, att = [], [], []
    for j in range(n_jobs):
        s = rng.lognormal(2.0, 0.8, 200)
        p, e = to_histogram(s, n_buckets)
        probs.append(p)
        edges.append(e)
        att.append(rng.uniform(0, 5))
    import jax.numpy as jnp
    P = jnp.asarray(np.asarray(probs), jnp.float32)
    E = jnp.asarray(np.asarray(edges), jnp.float32)
    A = jnp.asarray(np.asarray(att), jnp.float32)
    gittins_rank_hist(P, E, A).block_until_ready()   # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        gittins_rank_hist(P, E, A).block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    jobs_sweep = (16,) if smoke else (16, 64, 256, 1024)
    bucket_sweep = (10,) if smoke else (5, 10, 20, 40, 80)
    timings = {}                 # (jobs, buckets) -> s; smoke mode overlaps

    def timed(n_jobs, nb):
        if (n_jobs, nb) not in timings:
            timings[(n_jobs, nb)] = _time_gittins(n_jobs, nb)
        return timings[(n_jobs, nb)]

    # (a) queue-size sweep (stands in for arrival rate)
    for n_jobs in jobs_sweep:
        dt = timed(n_jobs, 10)
        csv.add(f"fig15a/gittins_runtime/jobs={n_jobs}", 1e6 * dt,
                f"{1e3*dt:.3f} ms/refresh")
    # (b) bucket-count sweep at a fixed queue
    for nb in bucket_sweep:
        dt = timed(16 if smoke else 256, nb)
        csv.add(f"fig15b/gittins_runtime/buckets={nb}", 1e6 * dt,
                f"{1e3*dt:.3f} ms/refresh")
    # (b') does more buckets help ACT? (paper: no)
    insts = workload(20 if smoke else 120, 120.0 if smoke else 300.0,
                     seed=seed)
    for nb in ((10,) if smoke else (5, 10, 40)):
        res = run_policy(insts, "gittins", n_buckets=nb)
        csv.add(f"fig15b/act_vs_buckets/nb={nb}", 0.0,
                f"mean_act={res.mean_act():.1f}s")
    # end-to-end scheduler priorities() cost inside a real run
    res = run_policy(insts, "gittins")
    per_call = res.policy_time_s / max(res.policy_calls, 1)
    csv.add("fig15/priorities_end_to_end", 1e6 * per_call,
            f"{1e3*per_call:.2f} ms/call over {res.policy_calls} calls")
