"""Fig. 13: warm-content hit ratio under LRU / EPWQ / Hermes prewarming —
(a) KV prefix caches across cache sizes, (b) LoRA adapters with a variant
pool (the paper's 200-adapter setup, scaled)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, clone_kb_with_loras, kb, run_policy, workload
from repro.apps.suite import SUITE, T_IN, T_OUT
from repro.apps.workload import make_workload


def _kv_hit(res):
    c = res.cache_stats["kv"]
    return c["hits"] / max(c["hits"] + c["misses"], 1)


def _lora_hit(res):
    c = res.cache_stats["lora"]
    return c["hits"] / max(c["hits"] + c["misses"], 1)


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    # ---- (a) KV prefix cache across capacities (paper: 8/16/32 GB) -------
    n, win = (500, 900.0) if paper_scale else (200, 400.0)
    caps = ((6, "8GB"), (12, "16GB"), (24, "32GB"))
    if smoke:
        n, win, caps = 30, 120.0, ((12, "16GB"),)
    insts = workload(n, win, seed=seed)
    for cap, label in caps:
        accs = {}
        for mode in ("lru", "epwq", "hermes"):
            res = run_policy(insts, "gittins", prewarm=mode, kv_capacity=cap)
            accs[mode] = res
            csv.add(f"fig13a/kv_hit/{label}/{mode}", 0.0,
                    f"hit={_kv_hit(res):.3f} mean_act={res.mean_act():.1f}s")
        up_lru = _kv_hit(accs["hermes"]) / max(_kv_hit(accs["lru"]), 1e-9) - 1
        up_ep = _kv_hit(accs["hermes"]) / max(_kv_hit(accs["epwq"]), 1e-9) - 1
        csv.add(f"fig13a/kv_improvement/{label}", 0.0,
                f"vs_lru=+{100*up_lru:.0f}% vs_epwq=+{100*up_ep:.0f}%")

    # ---- (b) LoRA pool: per-variant adapters, capacity-limited pool ------
    # churn regime (paper: 200 adapters vs max-cpu-loras 20): adapters get
    # evicted between an app's units; Hermes re-warms them ahead of the next
    # unit, LRU/EPWQ pay the reload at slot assignment
    n_var = 8 if paper_scale else (2 if smoke else 5)
    lkb = clone_kb_with_loras(kb(), n_var,
                              app_names=["KBQAV", "FEV", "CG", "CC", "EV"])
    from repro.apps.spec import AppSpec
    variant_apps = {}
    for name in list(lkb):
        base = name.split("#")[0]
        if "#" in name and base in SUITE:
            variant_apps[name] = SUITE[base]
    # build a workload over the variants with uniform sampling
    rng = np.random.default_rng(seed)
    from repro.apps.spec import sample_trajectory
    from repro.apps.workload import AppInstance, bursty_arrivals
    names = sorted(variant_apps)
    n2 = 400 if paper_scale else (30 if smoke else 160)
    times = bursty_arrivals(n2, win, rng)
    insts2 = []
    for i, t in enumerate(times):
        nm = names[int(rng.integers(len(names)))]
        insts2.append(AppInstance(app_id=f"lapp{i:05d}", app_name=nm,
                                  tenant=f"tenant{i % 8}", arrival=float(t),
                                  trajectory=sample_trajectory(variant_apps[nm],
                                                               rng)))
    for mode in ("lru", "epwq", "hermes"):
        res = run_policy(insts2, "gittins", prewarm=mode, lora_capacity=10,
                         knowledge=lkb)
        csv.add(f"fig13b/lora_hit/{mode}", 0.0,
                f"hit={_lora_hit(res):.3f} mean_act={res.mean_act():.1f}s")
