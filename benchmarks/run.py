"""Benchmark driver — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--paper] [--only fig9,fig13]

Prints ``name,us_per_call,derived`` CSV.  Default scale finishes on a laptop
CPU in minutes; ``--paper`` restores the paper's workload sizes.
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")

from benchmarks.common import Csv  # noqa: E402


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale workloads (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration: every section runs in "
                         "seconds (import/API drift canary, not a benchmark)")
    ap.add_argument("--only", default="",
                    help="comma list: fig9,fig11,fig12,fig13,fig14,fig15,"
                         "refresh,roofline,prewarm")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    csv = Csv()
    from benchmarks import (fig9_act, fig11_ddl, fig12_ablation, fig13_cache,
                            fig14_prewarm, fig15_overhead, prewarm,
                            refresh_tick, roofline)
    table = {"fig9": fig9_act, "fig11": fig11_ddl, "fig12": fig12_ablation,
             "fig13": fig13_cache, "fig14": fig14_prewarm,
             "fig15": fig15_overhead, "refresh": refresh_tick,
             "roofline": roofline, "prewarm": prewarm}
    if only and (unknown := only - set(table)):
        # a typo'd section must not silently no-op (CI would stay green)
        ap.error(f"unknown --only section(s): {sorted(unknown)}; "
                 f"known: {sorted(table)}")
    for name, mod in table.items():
        if only and name not in only:
            continue
        t0 = time.perf_counter()
        mod.run(csv, paper_scale=args.paper, seed=args.seed, smoke=args.smoke)
        csv.add(f"{name}/bench_wall", 1e6 * (time.perf_counter() - t0), "")
    csv.dump()


if __name__ == "__main__":
    main()
