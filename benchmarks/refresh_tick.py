"""Refresh-tick microbenchmark: looped vs batched priority refresh.

The Fig. 15 argument — scheduling overhead stays negligible at cluster
scale — only holds if the bucket-tick refresh is a batched hot path.  This
benchmark builds a queue of N live applications and times one full refresh
tick (re-draw every demand estimate from the PDGraphs, re-bucketize, re-rank)
under:

  looped    the seed implementation — one MC walk + one histogram per
            application per tick (``HermesScheduler(batched=False)``)
  batched   the whole queue packed into one jitted vmapped walk + one
            vectorized bucketize + one rank dispatch (``batched=True``)

plus the cheaper rank-only tick (demand estimates cached, re-rank only).

  PYTHONPATH=src python -m benchmarks.refresh_tick [--smoke] [--paper]
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")  # repo-root invocation without an installed package

from benchmarks.common import Csv, kb  # noqa: E402
from repro.apps.suite import T_IN, T_OUT  # noqa: E402
from repro.core.scheduler import HermesScheduler  # noqa: E402

MC_WALKERS = 128


def build_queue(knowledge, n_apps: int, batched: bool,
                seed: int = 11) -> HermesScheduler:
    sched = HermesScheduler(knowledge, policy="gittins", t_in=T_IN,
                            t_out=T_OUT, mc_walkers=MC_WALKERS, seed=seed,
                            batched=batched)
    names = sorted(knowledge)
    rng = np.random.default_rng(seed)
    for i in range(n_apps):
        aid = f"app{i:05d}"
        sched.on_arrival(aid, names[i % len(names)],
                         now=float(rng.uniform(0.0, 100.0)))
        sched.on_progress(aid, float(rng.uniform(0.0, 5.0)))
    return sched


def time_refresh(sched: HermesScheduler, iters: int,
                 resample: bool) -> float:
    sched.refresh_tick(100.0, resample=resample)       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        sched.refresh_tick(100.0, resample=resample)
    return (time.perf_counter() - t0) / iters


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    if smoke:
        sizes, iters = (16,), 1
    elif paper_scale:
        sizes, iters = (64, 256, 1024, 2048), 3
    else:
        sizes, iters = (64, 256, 1024), 3
    knowledge = kb()
    for n in sizes:
        t_loop = time_refresh(build_queue(knowledge, n, batched=False,
                                          seed=seed), iters, resample=True)
        t_batch = time_refresh(build_queue(knowledge, n, batched=True,
                                           seed=seed), iters, resample=True)
        csv.add(f"refresh_tick/full/looped/apps={n}", 1e6 * t_loop,
                f"{1e3 * t_loop:.2f} ms/tick")
        csv.add(f"refresh_tick/full/batched/apps={n}", 1e6 * t_batch,
                f"{1e3 * t_batch:.2f} ms/tick speedup={t_loop / t_batch:.1f}x")
    # rank-only tick (demand estimates cached between ticks)
    for n in sizes[-1:]:
        sched = build_queue(knowledge, n, batched=True, seed=seed)
        t_rank = time_refresh(sched, max(iters, 5), resample=False)
        csv.add(f"refresh_tick/rank_only/apps={n}", 1e6 * t_rank,
                f"{1e3 * t_rank:.3f} ms/tick")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (API drift canary)")
    ap.add_argument("--paper", action="store_true",
                    help="include the 2048-app point")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    csv = Csv()
    run(csv, paper_scale=args.paper, seed=args.seed, smoke=args.smoke)
    csv.dump()


if __name__ == "__main__":
    main()
