"""Refresh-tick microbenchmark: looped vs composed vs fused priority refresh.

The Fig. 15 argument — scheduling overhead stays negligible at cluster
scale — only holds if the bucket-tick refresh is a batched hot path.  This
benchmark builds a queue of N live applications and times one full refresh
tick (re-draw every demand estimate from the PDGraphs, re-bucketize,
re-rank) under:

  looped        the seed implementation — one MC walk + one histogram per
                application per tick (``HermesScheduler(refresh=RefreshConfig(mode="looped"))``)
  composed      PR 1: one jitted vmapped walk, host-side numpy bucketize,
                second jitted rank dispatch (``RefreshConfig(mode="composed")``)
  fused         the device-resident pipeline with the threefry walker —
                walk → bucketize → rank in ONE dispatch, bit-identical
                demand samples to composed (``RefreshConfig(mode="fused",
                walker="threefry")``): isolates the fusion gain
  fused_pallas  the PR-4 fused path: the counter-RNG ``pdgraph_walk``
                kernel package with phase compaction (``walker="pallas"``,
                pinned ``rank_in_kernel=False`` — the legacy
                walk -> histogram -> rank composition, kept as the A/B
                reference; Pallas kernel on TPU, its bit-identical jnp twin
                on CPU): fusion + RNG + compaction gains together
  fused_rank    the shipping one-pass configuration (ISSUE 9 defaults):
                ``pdgraph_walk_ranked`` carries each walker block from
                transition sampling to per-app histogram rows and Gittins
                ranks in ONE dispatch — VMEM-resident on TPU (no (A, W)
                totals round-trip), the lossless 16-bit quantized twin with
                the lane-gated multi-stage compaction schedule on CPU.
                Bit-identical ranks to fused_pallas
  fused_delta   the dirty-set delta refresh over the persistent slot store
                (``mode="fused_delta"``, the default): before each tick a realistic
                fraction (DIRTY_FRAC) of the queue takes a unit-transition
                event; the tick re-walks ONLY those slots and re-ranks the
                whole arena in place from persisted device histograms —
                the incremental-re-estimation claim, measured
  fused_delta_mesh1    the PR-5 mesh-sharded pipeline on a degenerate
                one-device mesh: same delta semantics, but stale-row-only
                ranking, packed-carrier dispatch and multi-stage walk
                compaction — the 1-shard scaling baseline of the mesh
  fused_delta_sharded  the mesh pipeline with the slot arena partitioned
                across min(8, device_count) devices via shard_map; one
                dispatch per tick walks each shard's dirty rows locally.
                Skipped on single-device runs — this module forces
                XLA_FLAGS=--xla_force_host_platform_device_count=8 when run
                directly (before jax loads), so the CPU arm exercises a
                real 8-way mesh; bit-identical ranks to fused_delta for
                the same placement
  fused_delta_skewed    the sharded pipeline fed a worst-case dirty set —
                every dirty slot lands on ONE shard (residue placement), so
                one shard walks everything while the rest idle: the
                measured dirty-imbalance straggler gap vs the uniform
                fused_delta_sharded arm
  fused_delta_balanced  the same skewed dirty set with walker-lane
                balancing ON (``lane_balance=0.25``): past the imbalance
                threshold the tick redistributes walker lanes round-robin
                across shards and all-gathers the packed result rows back
                to their owners — one collective buys back the straggler
                gap.  Bit-identical ranks to the unbalanced tick

plus the cheaper rank-only tick (demand estimates cached, re-rank only).

Every run (including ``--smoke``) also records machine-readable results in
``BENCH_refresh_tick.json`` so CI can archive the trajectory.

  PYTHONPATH=src python -m benchmarks.refresh_tick [--smoke] [--paper]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
from typing import Tuple

import numpy as np

sys.path.insert(0, "src")  # repo-root invocation without an installed package

# a CPU mesh needs forced host devices BEFORE jax initializes; when another
# harness (benchmarks.run) imported jax first this is a silent no-op and the
# sharded arm simply skips
if "jax" not in sys.modules and \
        "force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", "") and \
        not os.environ.get("REFRESH_TICK_NO_MESH"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from benchmarks.common import Csv, kb  # noqa: E402
from repro.apps.suite import T_IN, T_OUT  # noqa: E402
from repro.core.refresh_config import RefreshConfig  # noqa: E402
from repro.core.scheduler import HermesScheduler  # noqa: E402

MC_WALKERS = 128
JSON_PATH = "BENCH_refresh_tick.json"
# largest power of two <= device count (capped at 8): RefreshMesh requires a
# pow2 shard count, and hosts can expose e.g. 6 accelerators
MESH_SHARDS = 1 << (min(8, jax.device_count()).bit_length() - 1)

# prewarm=False isolates the rank-refresh cost (comparable across PRs);
# fused_prewarm measures the increment of computing the batched prewarm
# trigger matrix inside the same dispatch (arrival tracking + reduction)
ARMS = {
    "looped": dict(refresh=RefreshConfig(mode="looped"), prewarm=False),
    "composed": dict(refresh=RefreshConfig(mode="composed"), prewarm=False),
    "fused": dict(refresh=RefreshConfig(mode="fused", walker="threefry"),
                  prewarm=False),
    "fused_pallas": dict(refresh=RefreshConfig(mode="fused",
                                               rank_in_kernel=False),
                         prewarm=False),
    "fused_rank": dict(refresh=RefreshConfig(mode="fused"), prewarm=False),
    "fused_prewarm": dict(refresh=RefreshConfig(mode="fused"), prewarm=True),
    "fused_delta": dict(refresh=RefreshConfig(), prewarm=False),
    "fused_delta_prewarm": dict(refresh=RefreshConfig(), prewarm=True),
    "fused_delta_mesh1": dict(refresh=RefreshConfig(mesh_shards=1),
                              prewarm=False),
    "fused_delta_sharded": dict(refresh=RefreshConfig(
        mesh_shards=MESH_SHARDS), prewarm=False),
    "fused_delta_skewed": dict(refresh=RefreshConfig(
        mesh_shards=MESH_SHARDS), prewarm=False),
    "fused_delta_balanced": dict(refresh=RefreshConfig(
        mesh_shards=MESH_SHARDS, lane_balance=0.25), prewarm=False),
}
DELTA_ARMS = ("fused_delta", "fused_delta_prewarm", "fused_delta_mesh1",
              "fused_delta_sharded", "fused_delta_skewed",
              "fused_delta_balanced")
# the straggler pair feeds every dirty slot to ONE shard (residue 0)
SKEWED_ARMS = ("fused_delta_skewed", "fused_delta_balanced")
# per-tick fraction of the queue whose PDGraph position changes between two
# delta ticks — ~5-10% is what open-arrival sims at 1 s buckets actually see
DIRTY_FRAC = 0.08
# the per-app looped baseline is O(queue) dispatches per tick; past 1k apps
# it would dominate the whole benchmark wall time for a known-linear curve.
# The full-walk arms are O(queue) walk lanes per tick: at the 16k+ sizes
# (which exist to scale the DELTA/mesh arms) they'd add minutes of wall per
# size for known-linear curves, so only fused_pallas follows as the
# full-walk reference
ARM_MAX_APPS = {
    "looped": 1024,
    "composed": 4096,
    "fused": 4096,
    "fused_prewarm": 4096,
    "fused_delta_prewarm": 16384,
    "fused_pallas": 16384,
    "fused_rank": 16384,
}


def build_queue(knowledge, n_apps: int, arm: str,
                seed: int = 11) -> HermesScheduler:
    sched = HermesScheduler(knowledge, policy="gittins", t_in=T_IN,
                            t_out=T_OUT, mc_walkers=MC_WALKERS, seed=seed,
                            **ARMS[arm])
    names = sorted(knowledge)
    rng = np.random.default_rng(seed)
    for i in range(n_apps):
        aid = f"app{i:05d}"
        sched.on_arrival(aid, names[i % len(names)],
                         now=float(rng.uniform(0.0, 100.0)))
        sched.on_progress(aid, float(rng.uniform(0.0, 5.0)))
    return sched


def make_dirty_marker(sched: HermesScheduler, knowledge, n_apps: int,
                      seed: int, skewed: bool = False):
    """Simulate the between-tick churn a live queue sees: a DIRTY_FRAC
    subset of applications takes a unit-(re)start event, which marks their
    slots dirty through the real scheduler event path.  ``skewed`` lands
    every dirty slot on shard 0 (residue placement): the worst-case
    dirty-imbalance the straggler arms measure."""
    n_dirty = max(int(DIRTY_FRAC * n_apps), 1)
    rng = np.random.default_rng(seed + 1)

    def mark():
        if skewed:
            pool = n_apps // MESH_SHARDS
            picks = rng.choice(pool, size=min(n_dirty, pool),
                               replace=False) * MESH_SHARDS
        else:
            picks = rng.choice(n_apps, size=n_dirty, replace=False)
        for i in picks:
            aid = f"app{i:05d}"
            app = sched.apps[aid]
            unit = app.current_unit or knowledge[app.app_name].entry
            sched.on_unit_start(aid, unit, 100.0)
    return mark


def time_refresh(sched: HermesScheduler, iters: int,
                 resample: bool, mark=None) -> Tuple[float, float]:
    """(mean, min) seconds per tick over `iters` timed ticks.  The min is
    the noise-robust estimator the CI trend gate compares (a single
    contended iteration must not read as a regression); the mean stays the
    headline number."""
    if mark is not None:
        mark()
    sched.refresh_tick(100.0, resample=resample)       # warmup / compile
    sched.take_prewarm_plan()
    if mark is not None:
        # a delta arm's FIRST tick walks the whole (all-dirty-on-admit)
        # queue; extra warmup ticks compile the delta-sized dispatches so
        # the timed ticks measure steady state, not jit tracing (the
        # per-shard max dirty count straddles two padded shapes at small
        # queues — several draws are needed to have seen both)
        for _ in range(4):
            mark()
            sched.refresh_tick(100.0, resample=resample)
            sched.take_prewarm_plan()
    sched.fused_spill = 0          # count spill over the timed ticks only
    times = []
    for _ in range(iters):
        if mark is not None:
            mark()                 # event cost stays outside the tick timing
        t0 = time.perf_counter()
        sched.refresh_tick(100.0, resample=resample)
        # consume the batched plan like a real host would: an untaken stash
        # would otherwise make later ticks pay a growing merge cost
        sched.take_prewarm_plan()
        times.append(time.perf_counter() - t0)
    return sum(times) / len(times), min(times)


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    if smoke:
        # 5 iters even in smoke: the trend gate compares min-of-N, and at
        # millisecond ticks the min needs several draws to converge
        sizes, iters = (16,), 5
    elif paper_scale:
        sizes, iters = (256, 1024, 4096, 8192, 16384, 32768), 3
    else:
        sizes, iters = (256, 1024, 4096, 16384), 3
    knowledge = kb()
    records = []
    per_size = {}
    mins = {}
    for n in sizes:
        ticks = {}
        for arm in ARMS:
            if n > ARM_MAX_APPS.get(arm, 1 << 30):
                continue
            if arm in ("fused_delta_sharded",) + SKEWED_ARMS \
                    and MESH_SHARDS < 2:
                continue   # no real mesh (jax imported first / 1 device):
                # the arms would duplicate fused_delta_mesh1 — skip them
            sched = build_queue(knowledge, n, arm, seed=seed)
            mark = (make_dirty_marker(sched, knowledge, n, seed,
                                      skewed=arm in SKEWED_ARMS)
                    if arm in DELTA_ARMS else None)
            # delta ticks are tens of ms with compile-adjacent variance:
            # the min-of-N estimator (what the trend gate and the sharded
            # acceptance ratio compare) needs more draws to converge than
            # the second-long full-walk ticks do
            n_iters = iters + 4 if arm in DELTA_ARMS else iters
            t, t_min = time_refresh(sched, n_iters, resample=True, mark=mark)
            ticks[arm] = t
            mins[(arm, n)] = t_min
            derived = f"{1e3 * t:.2f} ms/tick"
            if arm != "looped" and "looped" in ticks:
                derived += f" vs_looped={ticks['looped'] / t:.1f}x"
            if arm.startswith("fused") and "composed" in ticks:
                derived += f" vs_composed={ticks['composed'] / t:.2f}x"
            if arm in DELTA_ARMS and "fused_pallas" in ticks:
                derived += f" vs_full_fused={ticks['fused_pallas'] / t:.2f}x"
            if arm == "fused_pallas":
                derived += f" spill/tick={sched.fused_spill / iters:.0f}"
            if arm == "fused_rank" and ("fused_pallas", n) in mins:
                ratio = mins[("fused_pallas", n)] / t_min
                derived += f" vs_fused_pallas_min={ratio:.2f}x"
            if arm == "fused_delta_sharded":
                ratio = mins[("fused_delta", n)] / t_min
                derived += (f" shards={MESH_SHARDS}"
                            f" vs_1shard_min={ratio:.2f}x"
                            f" spill={sched.fused_spill}")
            if arm == "fused_delta_skewed" \
                    and ("fused_delta_sharded", n) in mins:
                gap = t_min - mins[("fused_delta_sharded", n)]
                derived += f" straggler_gap_min={1e3 * gap:.2f}ms"
            if arm == "fused_delta_balanced" \
                    and ("fused_delta_skewed", n) in mins:
                skew = mins[("fused_delta_skewed", n)]
                derived += f" vs_skewed_min={skew / t_min:.2f}x"
            csv.add(f"refresh_tick/full/{arm}/apps={n}", 1e6 * t, derived)
            row = {"name": f"refresh_tick/full/{arm}/apps={n}",
                   "arm": arm, "apps": n, "us_per_call": 1e6 * t,
                   "ms_per_tick": 1e3 * t, "ms_per_tick_min": 1e3 * t_min}
            if arm in DELTA_ARMS:
                row["dirty_frac"] = DIRTY_FRAC
            rc = ARMS[arm]["refresh"]
            if rc.mesh_shards is not None:
                row["mesh_shards"] = rc.mesh_shards
            if rc.lane_balance is not None:
                row["lane_balance"] = rc.lane_balance
            if arm in SKEWED_ARMS:
                row["skewed_dirty"] = True
            records.append(row)
        per_size[n] = ticks
    # rank-only tick (demand estimates cached between ticks)
    for n in sizes[-1:]:
        sched = build_queue(knowledge, n, "composed", seed=seed)
        t_rank, t_rank_min = time_refresh(sched, max(iters, 5),
                                          resample=False)
        csv.add(f"refresh_tick/rank_only/apps={n}", 1e6 * t_rank,
                f"{1e3 * t_rank:.3f} ms/tick")
        records.append({"name": f"refresh_tick/rank_only/apps={n}",
                        "arm": "rank_only", "apps": n,
                        "us_per_call": 1e6 * t_rank,
                        "ms_per_tick": 1e3 * t_rank,
                        "ms_per_tick_min": 1e3 * t_rank_min})
    speedups = {
        f"{arm}_vs_composed@{n}": ticks["composed"] / ticks[arm]
        for n, ticks in per_size.items() if "composed" in ticks
        for arm in ("fused", "fused_pallas", "fused_rank") if arm in ticks}
    # the ISSUE-9 acceptance ratio: one-pass fused_rank vs the legacy
    # composition, min-of-N estimator, per size
    speedups.update({
        f"fused_rank_vs_fused_pallas_min@{n}":
            mins[("fused_pallas", n)] / mins[("fused_rank", n)]
        for n, ticks in per_size.items()
        if ("fused_rank", n) in mins and ("fused_pallas", n) in mins})
    speedups.update({
        f"fused_delta_vs_full@{n}": ticks["fused_pallas"] / ticks["fused_delta"]
        for n, ticks in per_size.items()
        if "fused_delta" in ticks and "fused_pallas" in ticks})
    # the sharded acceptance ratio uses the min-of-N estimator (same one the
    # trend gate compares): mesh tick vs the 1-shard delta arm, per size
    speedups.update({
        f"fused_delta_sharded_vs_1shard_min@{n}":
            mins[("fused_delta", n)] / mins[("fused_delta_sharded", n)]
        for n, ticks in per_size.items() if "fused_delta_sharded" in ticks})
    speedups.update({
        f"fused_delta_sharded_vs_mesh1_min@{n}":
            mins[("fused_delta_mesh1", n)] / mins[("fused_delta_sharded", n)]
        for n, ticks in per_size.items()
        if "fused_delta_sharded" in ticks and "fused_delta_mesh1" in ticks})
    # dirty-imbalance straggler accounting (min-of-N): the gap is the cost
    # of the worst-case skewed dirty set over the uniform sharded tick; the
    # eliminated fraction is how much of that gap lane balancing buys back
    # (the ISSUE-9 balanced-mesh acceptance wants >= 0.5)
    straggler = {}
    for n, ticks in per_size.items():
        k_s, k_u, k_b = (("fused_delta_skewed", n),
                         ("fused_delta_sharded", n),
                         ("fused_delta_balanced", n))
        if k_s in mins and k_u in mins:
            gap = mins[k_s] - mins[k_u]
            straggler[f"gap_ms_min@{n}"] = 1e3 * gap
            if k_b in mins and gap > 0:
                straggler[f"eliminated_frac@{n}"] = \
                    (mins[k_s] - mins[k_b]) / gap
    payload = {
        "benchmark": "refresh_tick",
        "smoke": smoke,
        "mc_walkers": MC_WALKERS,
        "sizes": list(sizes),
        "iters": iters,
        "dirty_frac": DIRTY_FRAC,
        "mesh_shards": MESH_SHARDS,
        "devices": jax.device_count(),
        "platform": platform.platform(),
        "rows": records,
        "speedup": speedups,
        "straggler": straggler,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {JSON_PATH}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (API drift canary)")
    ap.add_argument("--paper", action="store_true",
                    help="include the 8192-app point")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    csv = Csv()
    run(csv, paper_scale=args.paper, seed=args.seed, smoke=args.smoke)
    csv.dump()


if __name__ == "__main__":
    main()
