"""Fig. 14: prewarming aggressiveness knob K — per-app latency reduction vs
resource wastage, CG (docker backend) and PE (DNN backends)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, kb, run_policy
from repro.apps.spec import sample_trajectory
from repro.apps.suite import SUITE
from repro.apps.workload import AppInstance, bursty_arrivals


def _single_app_workload(app_name: str, n: int, win: float, seed: int):
    rng = np.random.default_rng(seed)
    times = bursty_arrivals(n, win, rng)
    return [AppInstance(app_id=f"{app_name}{i:04d}", app_name=app_name,
                        tenant="t0", arrival=float(t),
                        trajectory=sample_trajectory(SUITE[app_name], rng))
            for i, t in enumerate(times)]


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    n, win = (60, 600.0) if paper_scale else (40, 400.0)
    ks = (0.9, 0.7, 0.5, 0.3, 0.1)
    if smoke:
        n, win, ks = 8, 120.0, (0.5,)
    for app in ("CG", "PE"):
        # PE's tool models contend for one accelerator slot (the paper's
        # HuggingGPT setup where tools swap in/out of GPU memory)
        caps = dict(kv_capacity=4, lora_capacity=2)
        if app == "PE":
            caps["dnn_capacity"] = 1
        insts = _single_app_workload(app, n, win, seed)
        base = run_policy(insts, "gittins", prewarm="lru", **caps)
        for K in ks:
            res = run_policy(insts, "gittins", prewarm="hermes", K=K, **caps)
            waste = sum(c["wasted_warm_s"] for c in res.cache_stats.values())
            red = 100 * (1 - res.mean_act() / base.mean_act())
            csv.add(f"fig14/{app}/K={K}", 0.0,
                    f"latency_reduction={red:.1f}% wasted_warm_s={waste:.0f}")
        csv.add(f"fig14/{app}/baseline_lru", 0.0,
                f"mean_act={base.mean_act():.1f}s")
