"""Event-engine scale benchmark: calendar (array-native) vs heap simulator.

The claim behind the array-native engine (``SimConfig.engine="calendar"``):
a 100k-concurrent-application open-arrival trace — millions of scheduler
events — runs in minutes of wall time, where the seed's heap engine spends
its time in per-event Python tuple churn and per-tick O(queue) rank/key
rebuilds.  This benchmark measures both engines on the SAME overloaded
open-arrival trace:

* the **calendar** arm runs the trace to completion, sampling wall clock vs
  queue size (live applications, waiting tasks) every checkpoint;
* the **heap** arm is event-capped (``heap_event_cap``): running the seed
  engine to completion at this scale would take hours, so it processes the
  same FIRST ``heap_event_cap`` events of the trace — deep enough that its
  last checkpoint window sits in the 100k-live-app regime.

Two ratios come out, like-for-like by construction (bit-equivalent engines
drain identical micro-batches, so checkpoints align on event counts):

* ``speedup_same_prefix`` — wall clock over the identical event prefix
  (diluted by the cheap small-queue warm-up ramp);
* ``speedup_at_depth`` (headline) — events/sec inside the deepest common
  checkpoint window, i.e. the sustained rate at the 100k-concurrent-app
  operating point where the heap engine's per-tick O(live + waiting)
  rank/key rebuilds dominate.

The trace uses ``policy="fcfs_app"`` (a ``view_free`` policy: ranks come
from AppRuntime fields with no MC demand estimation, so the benchmark
isolates the host event engine rather than the refresh backbone — and the
heap arm stays measurable), ``preemptive=False`` and ``prewarm_mode="lru"``.
Engine bit-equivalence at this scale is pinned separately by
``tests/test_sim_engine.py``; the smoke configuration re-checks it here as
a drift canary.

Every run (including ``--smoke``) writes ``BENCH_sim_scale.json``; smoke
rows feed the CI trend gate against
``benchmarks/baselines/BENCH_sim_scale.smoke.json`` (the gate compares the
``ms_per_tick_min`` field, which for this benchmark carries milliseconds
per 1k events — the same monotone "smaller is better" contract).

  PYTHONPATH=src python -m benchmarks.sim_scale [--smoke]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import warnings

sys.path.insert(0, "src")  # repo-root invocation without an installed package

from benchmarks.common import Csv, kb  # noqa: E402
from repro.apps.suite import T_IN, T_OUT  # noqa: E402
from repro.apps.workload import make_open_workload  # noqa: E402
from repro.serving.simulator import ClusterSim, SimConfig  # noqa: E402

JSON_PATH = "BENCH_sim_scale.json"

# full scale: a heavily overloaded open-arrival trace (the nominal load is
# solved against the LLM pool alone, and docker/dnn pools add capacity, so
# saturating the cluster takes a nominal rho well past 1) — the backlog
# climbs through 100k+ concurrent live applications mid-run.  The heap arm
# is event-capped deep enough that its LAST checkpoint window sits in the
# 100k-live regime, where its per-tick O(live + waiting) rebuilds dominate.
FULL = dict(n_apps=150_000, duration_s=900.0, target_load=10.0,
            n_llm_slots=1024, n_docker_slots=2048, n_dnn_slots=128,
            heap_event_cap=400_000, checkpoint_every=20_000)
SMOKE = dict(n_apps=3000, duration_s=90.0, target_load=6.0,
             n_llm_slots=512, n_docker_slots=1024, n_dnn_slots=64,
             heap_event_cap=None, checkpoint_every=500)


def _trace(p, seed):
    return make_open_workload(
        p["duration_s"], t_in=T_IN, t_out=T_OUT,
        target_load=p["target_load"], n_service_slots=p["n_llm_slots"],
        process="gamma", cv=2.5, tenants=16, seed=seed,
        max_apps=p["n_apps"])


def _config(p, engine, seed):
    # refine=False: online demand conditioning feeds rank/prewarm views a
    # view_free policy never reads — dead per-transition work for BOTH arms.
    # The heap arm is the benchmark's intended deprecated-engine baseline,
    # so its construction warning is suppressed here.
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return SimConfig(policy="fcfs_app", preemptive=False, refine=False,
                         prewarm_mode="lru", engine=engine, seed=seed,
                         n_llm_slots=p["n_llm_slots"],
                         n_docker_slots=p["n_docker_slots"],
                         n_dnn_slots=p["n_dnn_slots"],
                         kv_capacity=4 * p["n_llm_slots"],
                         lora_capacity=2 * p["n_llm_slots"],
                         docker_capacity=p["n_docker_slots"],
                         dnn_capacity=p["n_dnn_slots"],
                         mc_walkers=16)


def _run_arm(knowledge, insts, p, engine, seed, max_events=None):
    """Run one engine over the trace, sampling (events, wall, live apps,
    waiting tasks) checkpoints.  Returns (result, record)."""
    sim = ClusterSim(knowledge, _config(p, engine, seed))
    every = p["checkpoint_every"]
    checkpoints = []
    t0 = time.perf_counter()

    def sample(s):
        if s.events_processed // every > len(checkpoints):
            checkpoints.append({
                "events": s.events_processed,
                "wall_s": time.perf_counter() - t0,
                "live_apps": len(s.sched._live),
                "waiting_tasks": int(sum(len(w)
                                         for w in s.waiting.values())),
            })

    res = sim.run(insts, max_events=max_events, progress=sample)
    wall = time.perf_counter() - t0
    events = sim.events_processed
    peak_live = max([c["live_apps"] for c in checkpoints],
                    default=len(sim.sched._live))
    rec = {
        "engine": engine, "apps": len(insts), "events": events,
        "wall_s": wall, "events_per_sec": events / max(wall, 1e-9),
        "peak_live_apps": int(peak_live),
        "completed_apps": len(res.acts),
        "makespan_s": res.makespan,
        "capped": max_events is not None,
        "checkpoints": checkpoints,
    }
    return res, rec


def _wall_at(checkpoints, events, fallback):
    """Wall clock when the run crossed ``events`` (first checkpoint past
    it); the like-for-like numerator/denominator of the prefix ratio."""
    for c in checkpoints:
        if c["events"] >= events:
            return c["wall_s"]
    return fallback


def _window_rate(checkpoints, i):
    """events/sec inside checkpoint window ``i`` (between checkpoints i-1
    and i; i=0 measures from the start of the run).  Engine checkpoints
    align exactly — bit-equivalent engines drain identical micro-batches,
    so the i-th checkpoint of both arms sits on the same event count."""
    c = checkpoints[i]
    e0 = checkpoints[i - 1]["events"] if i else 0
    w0 = checkpoints[i - 1]["wall_s"] if i else 0.0
    return (c["events"] - e0) / max(c["wall_s"] - w0, 1e-9)


def run(csv: Csv, smoke: bool = False, seed: int = 7):
    p = SMOKE if smoke else FULL
    knowledge = kb(60 if smoke else 200)
    insts = _trace(p, seed)
    print(f"# trace: {len(insts)} applications over {p['duration_s']}s")

    res_cal, rec_cal = _run_arm(knowledge, insts, p, "calendar", seed)
    cap = p["heap_event_cap"]
    res_heap, rec_heap = _run_arm(knowledge, insts, p, "heap", seed,
                                  max_events=cap)

    if smoke:
        # drift canary: full-run equivalence at smoke scale (the real
        # contract lives in tests/test_sim_engine.py)
        assert res_cal.completion_order == res_heap.completion_order
        assert res_cal.acts == res_heap.acts

    # whole-prefix ratio: wall over the identical event prefix both engines
    # processed (diluted by the cheap small-queue start of the trace)
    prefix = rec_heap["events"]
    cal_prefix_wall = _wall_at(rec_cal["checkpoints"], prefix,
                               rec_cal["wall_s"])
    speedup = rec_heap["wall_s"] / max(cal_prefix_wall, 1e-9)

    # headline: events/sec at the deepest operating point both arms share —
    # the heap arm's LAST checkpoint window (100k+ live apps at full scale).
    # This is the sustained-rate claim: what each engine does per second
    # once the queues are at scale, not amortized over the warm-up ramp.
    deep_i = min(len(rec_heap["checkpoints"]),
                 len(rec_cal["checkpoints"])) - 1
    if deep_i >= 0:
        deep_cal = _window_rate(rec_cal["checkpoints"], deep_i)
        deep_heap = _window_rate(rec_heap["checkpoints"], deep_i)
        deep_live = rec_heap["checkpoints"][deep_i]["live_apps"]
        deep_speedup = deep_cal / max(deep_heap, 1e-9)
    else:                     # trace too small for one full window
        deep_cal = rec_cal["events_per_sec"]
        deep_heap = rec_heap["events_per_sec"]
        deep_live = rec_cal["peak_live_apps"]
        deep_speedup = deep_cal / max(deep_heap, 1e-9)

    rows = []
    for rec in (rec_cal, rec_heap):
        n = rec["apps"]
        name = f"sim_scale/{rec['engine']}/apps={n}"
        ms_per_kevent = 1e6 * rec["wall_s"] / max(rec["events"], 1)
        csv.add(name, 1e3 * ms_per_kevent,
                f"{rec['events_per_sec']:,.0f} events/s "
                f"peak_live={rec['peak_live_apps']:,}"
                + (" (event-capped)" if rec["capped"] else ""))
        rows.append({"name": name, **rec,
                     # the trend gate compares ms_per_tick_min: here it
                     # carries ms per 1k drained events (same smaller-is-
                     # better contract as the refresh benchmark's tick)
                     "ms_per_tick": ms_per_kevent,
                     "ms_per_tick_min": ms_per_kevent})
    csv.add("sim_scale/speedup_same_prefix", speedup,
            f"calendar {speedup:.1f}x faster over first {prefix:,} events")
    csv.add("sim_scale/speedup_at_depth", deep_speedup,
            f"calendar {deep_cal:,.0f} vs heap {deep_heap:,.0f} events/s "
            f"at {deep_live:,} live apps")

    payload = {
        "benchmark": "sim_scale",
        "smoke": smoke,
        "params": {k: v for k, v in p.items()},
        "policy": "fcfs_app",
        "platform": platform.platform(),
        "rows": rows,
        "speedup": {
            "calendar_vs_heap_same_prefix": speedup,
            "prefix_events": prefix,
            "calendar_events_per_sec": rec_cal["events_per_sec"],
            "heap_events_per_sec": rec_heap["events_per_sec"],
            "calendar_vs_heap_at_depth": deep_speedup,
            "depth_live_apps": int(deep_live),
            "depth_calendar_events_per_sec": deep_cal,
            "depth_heap_events_per_sec": deep_heap,
        },
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {JSON_PATH}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (API drift canary)")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    csv = Csv()
    run(csv, smoke=args.smoke, seed=args.seed)
    csv.dump()


if __name__ == "__main__":
    main()
