"""Prewarm A/B benchmark: does acting on predicted demand pay for itself?

The Hermes claim under test — PDGraph-driven speculative prewarming takes
backend cold starts off the critical path — only means something against a
simulator that actually charges for cold backends.  This benchmark runs the
same workload through the cluster simulator with cold-start latencies
enabled under three backend policies:

  lru      reactive baseline: load on demand, evict least-recently-used
  epwq     CachedAttention-style: prefetch only for queued requests; the
           non-smoke run sweeps its prefetch window (how many upcoming
           trajectory units get prefetched: ``epwq_w2``/``epwq_w4`` arms)
           to probe whether the flat default window is the reason it barely
           helps at this scale
  hermes   the batched device-resident PrewarmPlan riding the fused refresh
           dispatch (per-(app, backend-class) arrival-quantile triggers)

and reports mean/p95 application completion time, cold-start stall seconds,
and the prewarm hit/miss/wasted-warm accounting.  Every run (including
``--smoke``) records machine-readable results in ``BENCH_prewarm.json`` so
CI can archive the trajectory (see docs/BENCHMARKS.md for the schema).

  PYTHONPATH=src python -m benchmarks.prewarm [--smoke] [--paper]
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

sys.path.insert(0, "src")  # repo-root invocation without an installed package

from benchmarks.common import Csv, kb, workload  # noqa: E402
from repro.serving.simulator import ClusterSim, SimConfig  # noqa: E402

JSON_PATH = "BENCH_prewarm.json"

ARMS = ("lru", "epwq", "hermes")
# prefetch-window sweep for the flat epwq baseline (non-smoke runs): w=1 is
# the plain `epwq` arm (current-unit-only, CachedAttention-style)
EPWQ_WINDOWS = (2, 4)


def run_arm(knowledge, insts, arm: str, *, seed: int, K: float = 0.5,
            epwq_window: int = 1):
    mode = "epwq" if arm.startswith("epwq") else arm
    cfg = SimConfig(policy="gittins", seed=seed, prewarm_mode=mode, K=K,
                    n_llm_slots=8, mc_walkers=128,
                    kv_capacity=8, lora_capacity=4, dnn_capacity=2,
                    epwq_window=epwq_window)
    t0 = time.perf_counter()
    res = ClusterSim(knowledge, cfg).run(list(insts))
    return res, time.perf_counter() - t0


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    n, win = (120, 600.0) if paper_scale else (60, 300.0)
    if smoke:
        n, win = 10, 120.0
    knowledge = kb()
    insts = workload(n, win, seed=seed)
    arms = [(a, 1) for a in ARMS]
    if not smoke:   # 3 window values total: epwq (w=1) + the sweep arms
        arms[2:2] = [(f"epwq_w{w}", w) for w in EPWQ_WINDOWS]
    records = []
    base = None
    for arm, w in arms:
        res, wall = run_arm(knowledge, insts, arm, seed=seed, epwq_window=w)
        if arm == "lru":
            base = res
        p = res.prewarm_stats
        red = 100 * (1 - res.mean_act() / base.mean_act())
        row = {
            "arm": arm, "apps": n, "mean_act_s": res.mean_act(),
            "epwq_window": w if arm.startswith("epwq") else None,
            "p95_act_s": res.p95_act(),
            "act_reduction_vs_lru_pct": red,
            "coldstart_stall_s": p["coldstart_stall_s"],
            "coldstart_events": p["coldstart_events"],
            "prewarm_pushed": p["prewarm_pushed"],
            "spec_loads": p["spec_loads"], "spec_used": p["spec_used"],
            "wasted_warm_s": p["wasted_warm_s"],
            "hits": p["hits"], "misses": p["misses"],
            "bench_wall_s": wall,
        }
        records.append(row)
        csv.add(f"prewarm/{arm}/apps={n}", 0.0,
                f"mean_act={res.mean_act():.1f}s "
                f"reduction={red:.1f}% "
                f"stall={p['coldstart_stall_s']:.0f}s "
                f"spec_used={p['spec_used']:.0f}/{p['spec_loads']:.0f} "
                f"wasted_warm={p['wasted_warm_s']:.0f}s")
    payload = {
        "benchmark": "prewarm",
        "smoke": smoke,
        "apps": n, "window_s": win,
        "platform": platform.platform(),
        "rows": records,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {JSON_PATH}")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (API drift canary)")
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale workload")
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    csv = Csv()
    run(csv, paper_scale=args.paper, seed=args.seed, smoke=args.smoke)
    csv.dump()


if __name__ == "__main__":
    main()
