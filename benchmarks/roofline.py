"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/<mesh>/<arch>__<shape>[__overrides].json and emits the
per-cell three-term roofline with the dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPS useful-compute ratio, and per-device memory footprint.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.config import SHAPES, applicable_shapes, get_config

RESULTS = Path("results/dryrun")


def load_cells(mesh: str = "single", overrides_tag: str = ""):
    out = {}
    d = RESULTS / mesh
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        parts = p.stem.split("__")
        tag = "__".join(parts[2:])
        if tag != overrides_tag:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_row(r: dict) -> str:
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | FAILED | | | | | | |")
    rl = r["roofline"]
    dom = rl["dominant"]
    frac = rl["roofline_fraction"]
    mem = r.get("memory_analysis", {})
    resident = (mem.get("argument_size_in_bytes", 0)) / 1e9
    return (f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:.1f} | "
            f"{rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} | "
            f"**{dom}** | {frac:.3f} | "
            f"{r.get('useful_flops_ratio') or 0:.2f} | {resident:.2f} |")


def table(mesh: str = "single", overrides_tag: str = "") -> str:
    cells = load_cells(mesh, overrides_tag)
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | roofline frac | useful FLOP ratio | args GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    from repro.config import list_configs
    for arch in list_configs():
        cfg = get_config(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if (arch, shape) in cells:
                lines.append(fmt_row(cells[(arch, shape)]))
            elif shape not in applicable_shapes(cfg):
                lines.append(f"| {arch} | {shape} | — | — | — | "
                             f"skip ({'quadratic attention' if shape == 'long_500k' else 'n/a'}) | | | |")
    return "\n".join(lines)


def summary(mesh: str = "single") -> Dict[str, float]:
    cells = load_cells(mesh)
    ok = [c for c in cells.values() if c.get("ok")]
    doms: Dict[str, int] = {}
    fracs = []
    for c in ok:
        doms[c["roofline"]["dominant"]] = doms.get(c["roofline"]["dominant"], 0) + 1
        if c["roofline"]["roofline_fraction"]:
            fracs.append(c["roofline"]["roofline_fraction"])
    import numpy as np
    return {"cells": len(ok), "dominant_counts": doms,
            "mean_fraction": float(np.mean(fracs)) if fracs else 0.0,
            "median_fraction": float(np.median(fracs)) if fracs else 0.0}


def run(csv, paper_scale: bool = False, seed: int = 0,
        smoke: bool = False):
    for mesh in ("single", "multi"):
        cells = load_cells(mesh)
        n_ok = sum(1 for c in cells.values() if c.get("ok"))
        csv.add(f"roofline/{mesh}/cells_ok", 0.0, f"{n_ok}/{len(cells)}")
        if mesh == "single" and cells:
            s = summary(mesh)
            csv.add("roofline/summary", 0.0,
                    f"mean_frac={s['mean_fraction']:.3f} "
                    f"median_frac={s['median_fraction']:.3f} "
                    f"dominant={s['dominant_counts']}")
        for (arch, shape), c in sorted(cells.items()):
            if not c.get("ok"):
                csv.add(f"roofline/{mesh}/{arch}/{shape}", 0.0, "FAILED")
                continue
            rl = c["roofline"]
            csv.add(f"roofline/{mesh}/{arch}/{shape}", 0.0,
                    f"dom={rl['dominant']} frac={rl['roofline_fraction']:.3f} "
                    f"lb={rl['step_s_lower_bound']*1e3:.1f}ms")


if __name__ == "__main__":
    print(table("single"))
    print()
    print(summary("single"))
