"""Shared benchmark setup: knowledge base, workloads, sim harness."""
from __future__ import annotations

import copy
import time
from typing import Dict, List, Optional

import numpy as np

from repro.apps.suite import SUITE, T_IN, T_OUT, build_knowledge_base
from repro.apps.workload import make_workload
from repro.core.pdgraph import PDGraph
from repro.serving.simulator import ClusterSim, SimConfig

_KB = None


def kb(n_trials: int = 200):
    global _KB
    if _KB is None:
        _KB = build_knowledge_base(n_trials=n_trials, seed=3)
    return _KB


def run_policy(instances, policy: str, *, prewarm="lru", seed=5,
               slots=8, refine=True, K=0.5, use_gittins=True,
               kv_capacity=16, lora_capacity=10, knowledge=None,
               n_buckets=10, dnn_capacity=2):
    cfg = SimConfig(policy=policy, seed=seed, prewarm_mode=prewarm,
                    n_llm_slots=slots, refine=refine, K=K,
                    kv_capacity=kv_capacity, lora_capacity=lora_capacity,
                    dnn_capacity=dnn_capacity,
                    mc_walkers=128, n_buckets=n_buckets)
    return ClusterSim(knowledge or kb(), cfg).run(list(instances))


def workload(n: int, window: float, seed=7, deadlines=False, apps=None):
    return make_workload(n, window, seed=seed, with_deadlines=deadlines,
                         t_in=T_IN, t_out=T_OUT, apps=apps)


def clone_kb_with_loras(base: Dict[str, PDGraph], n_variants: int,
                        app_names: Optional[List[str]] = None
                        ) -> Dict[str, PDGraph]:
    """Per-variant LoRA ids on every LLM unit (the Fig. 13b 200-adapter
    setup, scaled): app 'X' -> 'X#k' using 'lora_k'."""
    out: Dict[str, PDGraph] = {}
    for name, g in base.items():
        if app_names and name not in app_names:
            out[name] = g
            continue
        for k in range(n_variants):
            g2 = PDGraph.from_json(g.to_json())
            g2.app_name = f"{name}#{k}"
            for u in g2.units.values():
                if u.backend.kind == "llm":
                    u.backend = copy.replace(u.backend, lora=f"lora_{name}_{k}") \
                        if hasattr(copy, "replace") else \
                        type(u.backend)(u.backend.kind, u.backend.model,
                                        f"lora_{name}_{k}", u.backend.prefix)
            out[f"{name}#{k}"] = g2
    return out


class Csv:
    """Collects `name,us_per_call,derived` rows for benchmarks.run."""

    def __init__(self):
        self.rows: List[str] = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append(f"{name},{us_per_call:.2f},{derived}")

    def dump(self):
        print("name,us_per_call,derived")
        for r in self.rows:
            print(r)
