"""Fig. 12: demand-dynamicity ablation — Hermes, -refine, -refine-Gittins,
and Hermes-Oracle (true demands), normalized to Hermes."""
from __future__ import annotations

from benchmarks.common import Csv, run_policy, workload


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    n, win = (300, 600.0) if paper_scale else (200, 600.0)
    if smoke:
        n, win = 24, 120.0
    insts = workload(n, win, seed=seed)
    res = {
        "hermes": run_policy(insts, "gittins", refine=True, prewarm="hermes"),
        "-refine": run_policy(insts, "gittins", refine=False, prewarm="hermes"),
        "-refine-gittins": run_policy(insts, "srpt_mean", refine=False,
                                      prewarm="hermes"),
        "oracle": run_policy(insts, "oracle", prewarm="hermes"),
    }
    base = res["hermes"].mean_act()
    for name, r in res.items():
        csv.add(f"fig12/act/{name}", 0.0,
                f"mean={r.mean_act():.1f}s norm={r.mean_act()/base:.3f}")
    return res
