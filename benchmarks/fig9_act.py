"""Fig. 9/10: average + P95 ACT across arrival intensities, four schedulers.

Paper setup: 300 apps over 30/15/10-minute windows (1x/2x/3x) on one engine.
Default here is a 0.5-scaled run for wall-time; --paper restores full size.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Csv, kb, run_policy, workload

POLICIES = {"vllm(fcfs_req)": "fcfs_req", "parrot(fcfs_app)": "fcfs_app",
            "vtc": "vtc", "hermes(gittins)": "gittins"}


def run(csv: Csv, paper_scale: bool = False, seed: int = 7,
        smoke: bool = False):
    n = 300
    windows = {"1x": 1800.0, "2x": 900.0, "3x": 600.0}
    if smoke:
        n, windows = 16, {"1x": 120.0}
    out = {}
    for label, win in windows.items():
        insts = workload(n, win, seed=seed)
        for pname, pol in POLICIES.items():
            t0 = time.perf_counter()
            prewarm = "hermes" if pol == "gittins" else "lru"
            res = run_policy(insts, pol, prewarm=prewarm)
            wall = time.perf_counter() - t0
            out[(label, pname)] = res
            csv.add(f"fig9/act/{label}/{pname}", 1e6 * wall / max(len(res.acts), 1),
                    f"mean_act={res.mean_act():.1f}s p95={res.p95_act():.1f}s")
    # headline reductions at every intensity
    for label in windows:
        h = out[(label, "hermes(gittins)")]
        for base in ("vllm(fcfs_req)", "parrot(fcfs_app)", "vtc"):
            b = out[(label, base)]
            red = 100 * (1 - h.mean_act() / b.mean_act())
            red95 = 100 * (1 - h.p95_act() / b.p95_act())
            csv.add(f"fig9/reduction/{label}/vs_{base}", 0.0,
                    f"mean_-{red:.1f}% p95_-{red95:.1f}%")
    # CDF checkpoints (Fig. 9b)
    cdf_label = "2x" if "2x" in windows else next(iter(windows))
    h = out[(cdf_label, "hermes(gittins)")].act_values()
    v = out[(cdf_label, "vllm(fcfs_req)")].act_values()
    for q in (50, 80, 95, 99):
        csv.add(f"fig9/cdf_p{q}", 0.0,
                f"hermes={np.percentile(h, q):.1f}s vllm={np.percentile(v, q):.1f}s")
    return out
