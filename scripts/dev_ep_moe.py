"""EP MoE correctness vs dense oracle on a multi-device host mesh."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.testing import tiny_config
from repro.models import moe as X
from repro.distributed.sharding import ShardCtx, use_shard_ctx

cfg = tiny_config("qwen2-moe-a2.7b", capacity_factor=8.0)  # E=4 -> padded 16
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
params = X.moe_params(jax.random.PRNGKey(0), cfg, n=1, dtype=jnp.float32)
p = jax.tree_util.tree_map(lambda a: a[0], params)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)

y_dense = X.moe_apply_dense(p, x, cfg)
with use_shard_ctx(ShardCtx(mesh)), mesh:
    y_ep = jax.jit(lambda p_, x_: X.moe_apply(p_, x_, cfg.replace(moe_impl="ep")))(p, x)
err = float(jnp.max(jnp.abs(y_ep - y_dense)))
print("EP vs dense max err:", err)
assert err < 2e-4, err
print("OK")
