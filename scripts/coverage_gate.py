"""Test-quality gate: line-coverage floor for the scheduling core.

Runs the fast tier under ``pytest-cov`` restricted to ``repro.core`` — the
package every bit-identity contract in this repo ultimately pins — and
fails when total line coverage drops below the recorded floor.  Degrades
to a WARNING (exit 0) instead of failing when:

* ``pytest-cov`` is not importable (the pinned dev container does not ship
  it; CI installs it via the ``dev`` extra), or
* the platform is not Linux (platform-conditional branches make totals
  drift a little across OSes; only the Linux CI leg is the gate of record).

usage:
  python scripts/coverage_gate.py [--floor PCT] [--keep-report] [pytest args]

Extra arguments are forwarded to pytest (e.g. ``-k posterior``); by default
the whole fast tier runs.  The floor ratchets: when CI's measured total
comfortably exceeds it, raise the recorded value here in the same PR that
adds the coverage.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import platform
import subprocess
import sys

# Seeded conservatively below the measured CI total so runner-to-runner
# noise never flakes the gate; ratchet upward as the suite grows.
FLOOR_PCT = 70.0

REPORT = ".coverage_gate.json"


def _warn(msg: str) -> int:
    print(f"coverage_gate: WARNING — {msg} (gate skipped, exit 0)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--floor", type=float, default=FLOOR_PCT,
                    help=f"minimum total line coverage %% "
                         f"(default {FLOOR_PCT})")
    ap.add_argument("--keep-report", action="store_true",
                    help=f"leave {REPORT} behind for inspection")
    args, pytest_args = ap.parse_known_args(argv)

    if importlib.util.find_spec("pytest_cov") is None:
        return _warn("pytest-cov not installed "
                     "(pip install -e '.[dev]' provides it)")

    strict = platform.system() == "Linux"
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "pytest", "-q",
           "--cov=repro.core", "--cov-report=", f"--cov-report=json:{REPORT}",
           *pytest_args]
    proc = subprocess.run(cmd, env=env)
    if proc.returncode != 0:
        print("coverage_gate: FAIL — pytest itself failed "
              f"(exit {proc.returncode})")
        return proc.returncode

    try:
        with open(REPORT) as f:
            total = float(json.load(f)["totals"]["percent_covered"])
    except (OSError, KeyError, ValueError) as exc:
        return _warn(f"could not read coverage report: {exc}")
    finally:
        if not args.keep_report:
            try:
                os.remove(REPORT)
            except OSError:
                pass

    verdict = "ok" if total >= args.floor else "BELOW FLOOR"
    print(f"coverage_gate: repro.core line coverage {total:.1f}% "
          f"(floor {args.floor:.1f}%) — {verdict}")
    if total >= args.floor:
        return 0
    if not strict:
        return _warn(f"below floor on non-Linux ({platform.system()})")
    print("coverage_gate: FAIL — add tests or (if coverage legitimately "
          "moved) adjust FLOOR_PCT in scripts/coverage_gate.py")
    return 1


if __name__ == "__main__":
    sys.exit(main())
