import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, collections
import jax
from repro.config import SHAPES, get_config
from repro.distributed.sharding import ShardCtx, use_shard_ctx
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_functions
from repro.launch.dryrun import accounting_cfg, _DTYPE_BYTES, _SHAPE_RE
from repro.models.model import build_model

cfg = accounting_cfg(get_config("llama3-8b"), 1)
mesh = make_production_mesh()
ctx = ShardCtx(mesh, param_sharding=cfg.param_sharding)
model = build_model(cfg)
with use_shard_ctx(ctx), mesh:
    fn, args, in_sh, out_sh = cell_functions(model, SHAPES["decode_32k"], ctx)
    txt = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile().as_text()

def shape_bytes(tok):
    m = _SHAPE_RE.findall(tok)
    tot = 0
    for d, s in m:
        n = 1
        for x in s.split(","):
            if x: n *= int(x)
        tot += n * _DTYPE_BYTES.get(d, 4)
    return tot

per_op = collections.Counter()
for line in txt.splitlines():
    s = line.strip()
    m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([a-z0-9\[\],{}]+)\s+([a-z0-9\-]+)\(", s)
    if not m: continue
    out_tok, op = m.groups()
    b = shape_bytes(out_tok) + shape_bytes(s[s.index("("):])
    per_op[op] += b
for op, b in per_op.most_common(14):
    print(f"{op:28s} {b/1e9:8.2f} GB")
print("TOTAL", sum(per_op.values())/1e9)
