"""Benchmark trend gate: fail CI when a refresh-tick arm regresses.

Compares a fresh ``BENCH_refresh_tick.json`` (written by every
``benchmarks/refresh_tick.py`` invocation, including ``--smoke``) against a
committed baseline record and exits non-zero when any arm present in BOTH
files regressed by more than ``--max-regress-pct`` in ms/tick.

Honesty guards (cross-machine timing comparisons lie — see
docs/BENCHMARKS.md):

* arms whose baseline tick is below ``--min-ms`` are skipped — at smoke
  scale a sub-millisecond tick is jitter, not signal;
* when the baseline was recorded on a different platform string the gate
  downgrades to a warning (exit 0) unless ``--force`` — a laptop baseline
  must not fail a CI runner and vice versa;
* rows are matched by exact record name, so new arms/sizes pass until a
  baseline containing them is committed;
* each row compares the ``ms_per_tick_min`` (min-of-N) estimator, and
  ``--update`` folds a fresh record into the baseline as a per-row MAX —
  the baseline is the upper envelope of healthy runs, so one lucky fast
  draw can never poison it into flagging every later run;
* ``--field``/``--direction`` generalize the gate beyond latency: the
  overload benchmark gates ``--field goodput_per_s --direction max``
  (larger is better — regression = shrinkage, the envelope folds as a
  per-row MIN, and ``--min-ms 0`` keeps sub-1.0 goodput rows in play);
* ``--require ARM`` (repeatable) closes the new-arm blind spot of
  name-matching: the fresh record must contain at least one row whose
  ``arm`` field equals each required name, with the gated field present —
  an arm that silently stops being measured (skipped, renamed, crashed)
  fails the gate even though no shared row regressed.

Usage:
  python scripts/bench_trend.py BENCH_refresh_tick.json \
      --baseline benchmarks/baselines/BENCH_refresh_tick.smoke.json
  # refresh the baseline (run the benchmark a few times, folding each in):
  python scripts/bench_trend.py BENCH_refresh_tick.json --update \
      --baseline benchmarks/baselines/BENCH_refresh_tick.smoke.json

Stdlib-only (runs in the CI canary step before any install caching).
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


def row_value(r: dict, field: str):
    # the noise-robust min-of-N estimator when recorded ("<field>_min");
    # the plain field for records predating it (or deterministic metrics
    # like goodput that need no envelope estimator)
    v = r.get(field + "_min", r.get(field))
    return None if v is None else float(v)


def load_rows(path: str, field: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    rows = {}
    for r in payload["rows"]:
        v = row_value(r, field)
        if v is not None:          # rows without the field pass untouched
            rows[r["name"]] = v
    return payload, rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("fresh", help="freshly written BENCH_refresh_tick.json")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline record to compare against")
    ap.add_argument("--max-regress-pct", type=float, default=25.0,
                    help="fail when ms/tick grows more than this (%%)")
    ap.add_argument("--min-ms", type=float, default=1.0,
                    help="skip arms whose baseline value is below this")
    ap.add_argument("--field", default="ms_per_tick",
                    help="row field to gate on (a '<field>_min' estimator "
                         "is preferred when recorded)")
    ap.add_argument("--direction", choices=("min", "max"), default="min",
                    help="'min': smaller is better (latency; regression = "
                         "growth, baseline folds as an upper envelope); "
                         "'max': larger is better (goodput; regression = "
                         "shrinkage, baseline folds as a lower envelope)")
    ap.add_argument("--require", action="append", default=[],
                    metavar="ARM",
                    help="fail unless the fresh record has a row with this "
                         "'arm' field carrying the gated field (repeatable)")
    ap.add_argument("--force", action="store_true",
                    help="fail even across differing platform strings")
    ap.add_argument("--update", action="store_true",
                    help="fold the fresh record into the baseline "
                         "(per-row max of the min estimators; copies "
                         "verbatim when no baseline exists)")
    args = ap.parse_args(argv)

    if args.update:
        try:
            with open(args.baseline) as f:
                base_payload = json.load(f)
        except FileNotFoundError:
            shutil.copyfile(args.fresh, args.baseline)
            print(f"baseline created: {args.fresh} -> {args.baseline}")
            return 0
        with open(args.fresh) as f:
            fresh_payload = json.load(f)
        # the BASELINE payload stays the carrier: folding rows in must not
        # rewrite its platform string (that would mix machines in one
        # envelope and silently disarm the platform-match gate below)
        if fresh_payload.get("platform") != base_payload.get("platform") \
                and not args.force:
            print("bench_trend: refusing to fold a "
                  f"{fresh_payload.get('platform')!r} run into a "
                  f"{base_payload.get('platform')!r} baseline "
                  "(--force to restart the envelope on this machine)")
            return 1
        if fresh_payload.get("platform") != base_payload.get("platform"):
            shutil.copyfile(args.fresh, args.baseline)   # --force: restart
            print(f"baseline restarted on this platform: {args.baseline}")
            return 0
        by_name = {r["name"]: r for r in base_payload["rows"]}
        # per-fold growth cap at half the gate threshold: the envelope may
        # absorb noise peaks, but a sequence of sub-threshold regressions
        # must not ratchet it upward unbounded (slow drift stays visible
        # against the intentionally-refreshed committed baseline)
        cap = args.max_regress_pct / 200.0
        for r in fresh_payload["rows"]:
            prev = by_name.get(r["name"])
            if prev is None:
                by_name[r["name"]] = r
                continue
            pv, fv = row_value(prev, args.field), row_value(r, args.field)
            if pv is None or fv is None:
                by_name[r["name"]] = r
                continue
            worse = fv > pv if args.direction == "min" else fv < pv
            if worse:
                r = dict(r)
                folded = min(fv, pv * (1.0 + cap)) \
                    if args.direction == "min" else max(fv, pv * (1.0 - cap))
                key = args.field + "_min" if args.field + "_min" in r \
                    else args.field
                r[key] = folded
                by_name[r["name"]] = r
        base_payload["rows"] = [by_name[k] for k in sorted(by_name)]
        with open(args.baseline, "w") as f:
            json.dump(base_payload, f, indent=2)
        print(f"baseline envelope updated: {args.baseline} "
              f"({len(by_name)} rows)")
        return 0

    fresh_payload, fresh = load_rows(args.fresh, args.field)
    base_payload, base = load_rows(args.baseline, args.field)

    if args.require:
        have = {r.get("arm") for r in fresh_payload["rows"]
                if row_value(r, args.field) is not None}
        missing = [a for a in args.require if a not in have]
        if missing:
            print(f"bench_trend: FAIL — required arm(s) missing from "
                  f"{args.fresh}: {missing} (measured: {sorted(have)})")
            return 1

    cross = fresh_payload.get("platform") != base_payload.get("platform")
    shared = sorted(set(fresh) & set(base))
    if not shared:
        print("bench_trend: no shared rows between fresh and baseline; "
              "commit a fresh baseline (--update)")
        return 0

    regressions = []
    print(f"{'row':<52} {'base':>9} {'fresh':>9} {'delta':>8}")
    for name in shared:
        b, f = base[name], fresh[name]
        if b < args.min_ms or b <= 0.0:
            continue
        # positive pct always means "worse by pct" in the gated direction
        pct = 100.0 * (f - b) / b
        if args.direction == "max":
            pct = -pct
        flag = " <-- REGRESSION" if pct > args.max_regress_pct else ""
        unit = "ms" if args.field.startswith("ms") else ""
        print(f"{name:<52} {b:>7.4g}{unit} {f:>7.4g}{unit} "
              f"{pct:>+7.1f}%{flag}")
        if pct > args.max_regress_pct:
            regressions.append((name, b, f, pct))

    if regressions and cross and not args.force:
        print(f"\nbench_trend: {len(regressions)} regression(s) but the "
              "baseline was recorded on a different platform "
              f"({base_payload.get('platform')!r} vs "
              f"{fresh_payload.get('platform')!r}); warning only "
              "(--force to fail anyway)")
        return 0
    if regressions:
        print(f"\nbench_trend: FAIL — {len(regressions)} arm(s) regressed "
              f"more than {args.max_regress_pct:.0f}% vs {args.baseline}")
        return 1
    print("\nbench_trend: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
