"""Dev-loop smoke: every family, train loss + prefill + decode on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config, list_configs
from repro.models.model import build_model
from repro.testing import tiny_config

rng = jax.random.PRNGKey(0)


def batch_for(cfg, B=2, S=16):
    if cfg.family == "encdec":
        return {"frames": jnp.zeros((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16),
                "tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.ones((B, S), jnp.int32),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family == "vlm":
        P = cfg.vision_patches
        return {"tokens": jnp.ones((B, S), jnp.int32),
                "patch_embeds": jnp.zeros((B, P, cfg.d_model), jnp.bfloat16),
                "labels": jnp.ones((B, S), jnp.int32),
                "loss_mask": jnp.ones((B, S), jnp.float32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32),
            "loss_mask": jnp.ones((B, S), jnp.float32)}


fails = []
for name in list_configs():
    cfg = tiny_config(name)
    m = build_model(cfg)
    try:
        params = m.init(rng, max_seq=64)
        batch = batch_for(cfg)
        loss = jax.jit(m.train_loss)(params, batch)
        assert np.isfinite(float(loss)), f"{name}: loss not finite"
        pre = {k: v for k, v in batch.items() if k not in ("labels", "loss_mask")}
        caches, logits = jax.jit(m.prefill)(params, pre)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)[..., :cfg.vocab_size]))
        # pad caches to max_seq for decode
        def pad(c, path=""):
            return c
        tok = jnp.ones((2, 1), jnp.int32)
        S0 = batch["tokens"].shape[1] + (cfg.vision_patches if cfg.family == "vlm" else 0)
        # grow attention caches to 32
        def grow(x):
            if x.ndim >= 3 and x.shape[2] == S0:  # (n, B, S, ...) attn cache
                pad_amt = [(0, 0)] * x.ndim
                pad_amt[2] = (0, 32 - S0)
                return jnp.pad(x, pad_amt)
            return x
        caches = jax.tree_util.tree_map(grow, caches)
        caches2, logits2 = jax.jit(m.decode)(params, caches, tok, jnp.asarray(S0, jnp.int32))
        assert np.all(np.isfinite(np.asarray(logits2, np.float32)[..., :cfg.vocab_size]))
        print(f"OK   {name:26s} loss={float(loss):.3f}")
    except Exception as e:
        fails.append(name)
        import traceback
        print(f"FAIL {name}: {type(e).__name__}: {e}")
        if "-v" in sys.argv:
            traceback.print_exc()

print("FAILS:", fails or "none")
sys.exit(1 if fails else 0)
