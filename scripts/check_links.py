#!/usr/bin/env python
"""Markdown link checker for the docs CI job (stdlib only).

Validates every inline markdown link/image ``[text](target)`` in the given
files/directories:

* relative paths must exist in the repository (anchors are stripped;
  ``#section`` anchors within a file are not resolved — heading drift is a
  review concern, dead files are a CI concern);
* bare in-repo anchors (``#section``), external schemes (``http://``,
  ``https://``, ``mailto:``), and forge-relative paths that escape the
  repository root (GitHub badge URLs like ``../../actions/...``) are
  accepted without network access.

Exit code 1 lists every dead link.  Usage:

    python scripts/check_links.py README.md ROADMAP.md docs/
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links/images; skips reference-style and autolinks on purpose —
# the repo's docs use inline style throughout
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_CODE_FENCE = re.compile(r"^(```|~~~)")


def iter_md_files(args):
    for a in args:
        p = Path(a)
        if p.is_dir():
            yield from sorted(p.rglob("*.md"))
        elif p.suffix == ".md":
            yield p


def check_file(md: Path) -> list:
    dead = []
    in_fence = False
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if _CODE_FENCE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:                       # same-file anchor
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.is_relative_to(Path.cwd().resolve()):
                continue               # forge-relative (badge) — not a file
            if not resolved.exists():
                dead.append(f"{md}:{lineno}: dead link -> {target}")
    return dead


def main(argv):
    if not argv:
        print(__doc__)
        return 2
    dead = []
    n = 0
    for md in iter_md_files(argv):
        n += 1
        dead.extend(check_file(md))
    for d in dead:
        print(d)
    print(f"# checked {n} markdown file(s): "
          f"{'FAIL' if dead else 'ok'} ({len(dead)} dead link(s))")
    return 1 if dead else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
