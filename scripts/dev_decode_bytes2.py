import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, collections
import jax
from repro.config import SHAPES, get_config
from repro.distributed.sharding import ShardCtx, use_shard_ctx
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import cell_functions
from repro.launch.dryrun import accounting_cfg, _DTYPE_BYTES, _SHAPE_RE
from repro.models.model import build_model

def profile(k):
    cfg = accounting_cfg(get_config("llama3-8b"), k)
    mesh = make_production_mesh()
    ctx = ShardCtx(mesh, param_sharding=cfg.param_sharding)
    model = build_model(cfg)
    with use_shard_ctx(ctx), mesh:
        fn, args, in_sh, out_sh = cell_functions(model, SHAPES["decode_32k"], ctx)
        c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
        txt = c.as_text()
        ca = c.cost_analysis()
    per_op = collections.Counter()
    biggest = []
    for line in txt.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([a-z0-9\[\],{}]+)\s+([a-z0-9\-]+)\(", s)
        if not m: continue
        out_tok, op = m.groups()
        def sb(tok):
            tot = 0
            for d, sh in _SHAPE_RE.findall(tok):
                n = 1
                for x in sh.split(","):
                    if x: n *= int(x)
                tot += n * _DTYPE_BYTES.get(d, 4)
            return tot
        b = sb(out_tok) + sb(s[s.index("("):])
        per_op[op] += b
        biggest.append((b, op, s[:110]))
    return per_op, float(ca.get("bytes accessed", 0)), biggest

p1, b1, _ = profile(1)
p2, b2, big2 = profile(2)
print(f"cost_analysis bytes: 1p={b1/1e9:.2f}GB 2p={b2/1e9:.2f}GB delta/layer={(b2-b1)/1e9:.2f}GB")
print("per-op parsed delta (GB):")
for op in sorted(set(p1) | set(p2), key=lambda o: -(p2[o]-p1[o])):
    d = (p2[op] - p1[op]) / 1e9
    if abs(d) > 0.005:
        print(f"  {op:26s} {d:8.3f}")
print("biggest single ops in 2p:")
for b, op, s in sorted(big2, reverse=True)[:8]:
    print(f"  {b/1e9:6.2f}GB {op:20s} {s[:95]}")
