"""Dev smoke: build KB, run a small workload under several policies."""
import time

import numpy as np

from repro.apps.suite import SUITE, T_IN, T_OUT, build_knowledge_base
from repro.apps.workload import make_workload
from repro.serving.simulator import ClusterSim, SimConfig

t0 = time.time()
kb = build_knowledge_base(n_trials=200, seed=3)
print(f"KB built in {time.time()-t0:.1f}s")

insts = make_workload(60, 300.0, seed=11, t_in=T_IN, t_out=T_OUT)
sizes = {}
for i in insts:
    sizes[i.app_name] = sizes.get(i.app_name, 0) + 1
print("mix:", sizes)

for policy in ("fcfs_req", "fcfs_app", "vtc", "srpt_mean", "gittins", "oracle"):
    t0 = time.time()
    cfg = SimConfig(policy=policy, seed=5,
                    prewarm_mode="hermes" if policy == "gittins" else "lru")
    res = ClusterSim(kb, cfg).run(list(insts))
    print(f"{policy:10s} mean_act={res.mean_act():8.1f} p95={res.p95_act():8.1f} "
          f"policy_ms/call={1000*res.policy_time_s/max(res.policy_calls,1):.2f} "
          f"wall={time.time()-t0:.1f}s n={len(res.acts)}")
